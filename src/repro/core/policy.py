"""Unified backend policy: one knob surface for every dispatchable stage.

The engine grew five independent backend toggles as kernels landed —
`join_backend` (Phase-3 MBR join), `join_impl` (relational primitive),
`rank_backend` (merge-join rank pass), `probe_backend` (Bloom CS probes),
`kcap_auto` (fused partial-width tuning) — plus the Phase-1 `descend`
route, each with its own registry, its own None-vs-"auto" convention, and
its own resolution point scattered across the call stack. `BackendPolicy`
collapses them into one frozen dataclass with a single ``resolve()`` that
validates every stage against its registry and pins the "auto" choices
(platform detection runs once, here, not per call):

    ExecConfig(policy=BackendPolicy(rank="interpret", descend="kernel"))

Resolution happens once per config (`ExecConfig.__post_init__`) and the
resolved stages are stamped onto the `QueryPlan`, so the per-block hot
paths read plain strings — zero dispatch logic left at execution time.
The legacy ExecConfig kwargs still work as deprecation shims and fold into
the policy bit-identically.
"""
from __future__ import annotations

import dataclasses

# fused partial-width modes: "fixed" = the static min(max(k, 64), batch_cols)
# floor; "auto" = the per-engine EWMA KcapTuner (spatial_join.KcapTuner)
KCAP_MODES = ("fixed", "auto")


@dataclasses.dataclass(frozen=True)
class BackendPolicy:
    """Backend selection for every dispatchable engine stage.

    Each field names a registry entry (all accept "auto"):

    - ``join``:    Phase-3 MBR distance join — spatial_join.JOIN_BACKENDS
                   ("auto" | "numpy" | "kernel" | "fused")
    - ``impl``:    relational join primitive — core/join.JOIN_IMPLS
                   ("auto" | "merge" | "looped")
    - ``rank``:    merge-join rank pass — kernels/ops.RANK_BACKENDS
                   ("auto" | "numpy" | "cpu" | "kernel" | "interpret")
    - ``probe``:   Bloom CS probes — charsets.PROBE_BACKENDS
                   ("auto" | "numpy" | "kernel" | "interpret")
    - ``descend``: Phase-1 candidate-node traversal —
                   squadtree.DESCEND_BACKENDS
                   ("auto" | "numpy" | "kernel" | "interpret")
    - ``kcap``:    fused partial-width mode — KCAP_MODES ("fixed" | "auto")

    Every backend of every stage is bit-identical to every other backend of
    the same stage (the kernel tests assert it), so the policy is purely a
    performance/portability choice.
    """
    join: str = "auto"
    impl: str = "auto"
    rank: str = "auto"
    probe: str = "auto"
    descend: str = "auto"
    kcap: str = "fixed"

    def resolve(self) -> "BackendPolicy":
        """Validate every stage and pin the "auto" choices.

        Returns a policy with no "auto" left (idempotent: resolving a
        resolved policy is a no-op). Raises ValueError naming the stage on
        any unknown backend.

        Resolution also consults the fault layer's circuit breakers
        (core/fault.demote_stage): a stage whose resolved backend sits on a
        breaker-open op reroutes to its safe fallback here, at plan time, so
        later queries skip the broken backend at zero per-block cost. With a
        clean breaker registry (the normal case) demotion is a no-op.
        """
        from ..kernels import ops
        from . import charsets, fault, spatial_join, squadtree
        from .join import resolve_join_impl

        if self.kcap not in KCAP_MODES:
            raise ValueError(f"unknown kcap mode {self.kcap!r} "
                             f"(expected one of {KCAP_MODES})")
        return BackendPolicy(
            join=fault.demote_stage(
                "join", spatial_join.resolve_join_backend(self.join)),
            impl=resolve_join_impl(self.impl),
            rank=fault.demote_stage(
                "rank", ops.resolve_rank_backend(self.rank)),
            probe=fault.demote_stage(
                "probe", charsets.resolve_probe_backend(self.probe)),
            descend=fault.demote_stage(
                "descend", squadtree.resolve_descend_backend(self.descend)),
            kcap=self.kcap,
        )

    @property
    def resolved(self) -> bool:
        return "auto" not in (self.join, self.impl, self.rank,
                              self.probe, self.descend)
