"""SASRec retrieval serving with STREAK block-wise top-k early termination.

Trains a small SASRec for a few steps, then serves top-k retrieval over the
catalog two ways — full blocked scan vs STREAK early-terminating scan — and
verifies they agree while the STREAK path reads fewer blocks (the paper's
N-Plan threshold test as a recsys serving feature).

    PYTHONPATH=src python examples/serve_topk.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.data.recsys import InteractionStream
from repro.models import sasrec
from repro.serve import retrieval
from repro.train import loop, optim


def main() -> None:
    cfg = sasrec.SASRecConfig(n_items=20_000, embed_dim=32, n_blocks=2,
                              seq_len=20, d_ff=32)
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    stream = InteractionStream(cfg.n_items, cfg.seq_len, batch=64, seed=0)

    def loss_fn(p, seq, pos, neg):
        return sasrec.bpr_loss(p, seq, pos, neg, cfg)

    tr = loop.Trainer(loss_fn, params,
                      loop.TrainerConfig(ckpt_dir="/tmp/repro_sasrec",
                                         ckpt_every=1000, log_every=20),
                      optim.AdamWConfig(lr=1e-3, warmup_steps=10,
                                        total_steps=200, weight_decay=0.0))
    tr.fit(lambda s: tuple(jnp.asarray(x) for x in stream.batch(s)),
           n_steps=60)
    params = tr.params

    # ---- retrieval: full scan vs STREAK early-out ----------------------
    # Production catalogs are popularity-skewed and trained item norms track
    # popularity [e.g. YouTube DNN]; model that skew explicitly so the
    # norm-sorted block bounds are meaningful (a uniform-norm catalog has
    # nothing to terminate early on).
    rng = np.random.default_rng(7)
    popularity = jnp.asarray(
        rng.zipf(1.4, size=cfg.n_items).clip(1, 1000).astype(np.float32))
    params["item_embed"] = params["item_embed"] \
        * jnp.log1p(popularity)[:, None]

    seq, _, _ = stream.batch(999)
    state = sasrec.user_state(params, jnp.asarray(seq[:4]), cfg)
    items = params["item_embed"]
    block = 1024
    full_s, full_i = retrieval.blocked_topk(state, items, k=10, block=block)

    items_sorted, order = retrieval.sort_items_by_norm(items, block)
    bounds = retrieval.block_bounds(items_sorted, block)
    s2, i2, blocks_read = retrieval.streak_topk(
        state, items_sorted, order.astype(jnp.int32), bounds, k=10,
        block=block)

    nb = -(-cfg.n_items // block)
    print(f"\ncatalog {cfg.n_items} items in {nb} blocks of {block}")
    print(f"STREAK early-out read {int(blocks_read)}/{nb} blocks "
          f"({int(blocks_read)/nb*100:.0f}%)")
    for u in range(4):
        a = set(np.asarray(full_i[u]).tolist())
        b = set(np.asarray(i2[u]).tolist())
        assert a == b, "early-out must be exact"
    print("exactness check: early-out top-10 == full-scan top-10 for all "
          "users")


if __name__ == "__main__":
    main()
