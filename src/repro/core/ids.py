"""The (S, Z, I, L) identifier codec (paper §3.1.1).

Layout of a 64-bit identifier (MSB -> LSB); bit 63 stays 0 so ids are
non-negative int64:

    [63] 0 | [62] S | [42..61] Z-path (2*L_MAX = 20 bits, level-aligned)
    | [38..41] L (4 bits) | [0..37] I (38 bits local id)

``Z`` is the Morton path of the deepest node that fully encloses the object,
*left-aligned* to L_MAX levels (a node at level l occupies the top 2l bits of
the field, with zeros below). Because Z sits directly under S, every quadtree
subtree owns one contiguous id interval -> I-Range pruning is two comparisons.
L disambiguates objects assigned to an ancestor from those assigned to its
first child (both share the zero-padded path). The paper fixes |L| = 4 and
L_MAX = 10 ("little benefit beyond 4^10 quadrants"); we keep those defaults
but parameterize for tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

L_MAX = 10
Z_BITS = 2 * L_MAX          # 20
L_BITS = 4
I_BITS = 62 - Z_BITS - L_BITS  # 38

S_SHIFT = 62
Z_SHIFT = L_BITS + I_BITS      # 42
L_SHIFT = I_BITS               # 38

S_MASK = np.int64(1) << np.int64(S_SHIFT)
Z_MASK = ((np.int64(1) << np.int64(Z_BITS)) - 1) << np.int64(Z_SHIFT)
L_MASK = ((np.int64(1) << np.int64(L_BITS)) - 1) << np.int64(L_SHIFT)
I_MASK = (np.int64(1) << np.int64(I_BITS)) - 1

MAX_LOCAL = (1 << I_BITS) - 1


@dataclasses.dataclass(frozen=True)
class SpatialId:
    spatial: bool
    zpath: int   # morton path at the object's own level (2*level bits)
    level: int
    local: int


def encode(zpath: np.ndarray, level: np.ndarray, local: np.ndarray) -> np.ndarray:
    """Vectorized spatial-id encode. `zpath` is at the object's own level."""
    zpath = np.asarray(zpath, dtype=np.int64)
    level = np.asarray(level, dtype=np.int64)
    local = np.asarray(local, dtype=np.int64)
    z_aligned = zpath << (2 * (L_MAX - level))
    return (
        S_MASK
        | (z_aligned << np.int64(Z_SHIFT))
        | (level << np.int64(L_SHIFT))
        | (local & I_MASK)
    )


def decode(ids: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (spatial?, zpath-at-own-level, level, local)."""
    ids = np.asarray(ids, dtype=np.int64)
    spatial = (ids & S_MASK) != 0
    level = (ids & L_MASK) >> np.int64(L_SHIFT)
    z_aligned = (ids & Z_MASK) >> np.int64(Z_SHIFT)
    zpath = z_aligned >> (2 * (L_MAX - level))
    local = ids & I_MASK
    return spatial, zpath, level, local


def is_spatial(ids: np.ndarray) -> np.ndarray:
    return (np.asarray(ids, dtype=np.int64) & S_MASK) != 0


def subtree_interval(zpath: np.ndarray, level: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Closed id interval [lo, hi] owned by the subtree of node (zpath, level).

    This *is* the node's I-Range: by construction it covers every object whose
    deepest enclosing node lies in the subtree (paper §3.1.2).
    """
    zpath = np.asarray(zpath, dtype=np.int64)
    level = np.asarray(level, dtype=np.int64)
    z_lo = zpath << (2 * (L_MAX - level))
    z_hi = (zpath + 1) << (2 * (L_MAX - level))
    # `lo` carries the node's own level: an object assigned to an ANCESTOR has
    # a zero-padded Z-path that coincides with the leftmost-descendant prefix,
    # and only the L field (which sorts below Z) separates it from the subtree
    # -- this is exactly why the codec stores L (paper §3.1.1).
    lo = S_MASK | (z_lo << np.int64(Z_SHIFT)) | (level << np.int64(L_SHIFT))
    # the last sibling's z_hi overflows the Z field: saturate to the maximum
    # spatial id instead of wrapping into the S bit.
    max_id = S_MASK | Z_MASK | L_MASK | I_MASK
    hi = np.where(z_hi >= np.int64(1) << np.int64(Z_BITS),
                  max_id, (S_MASK | (z_hi << np.int64(Z_SHIFT))) - 1)
    return lo, hi


def node_own_interval(zpath: np.ndarray, level: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Closed id interval of objects assigned to the node itself (same Z, L)."""
    zpath = np.asarray(zpath, dtype=np.int64)
    level = np.asarray(level, dtype=np.int64)
    z_aligned = zpath << (2 * (L_MAX - level))
    base = S_MASK | (z_aligned << np.int64(Z_SHIFT)) | (level << np.int64(L_SHIFT))
    return base, base | I_MASK


def nonspatial_ids(n: int, start: int = 1) -> np.ndarray:
    """Plain entity ids (S bit clear). 0 is reserved as NULL."""
    return np.arange(start, start + n, dtype=np.int64)
