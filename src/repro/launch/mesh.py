"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch sharding and carries the cross-pod
gradient all-reduce (optionally int8-compressed, dist/grad_compression.py).

Defined as a function so importing this module never touches jax device
state (the dry-run forces 512 host devices BEFORE any jax import).
"""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    """axis_types only exists on newer jax; omit it where absent."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_kwargs(2))
