"""Optimized-HLO collective extraction with loop-aware accounting.

cost_analysis() has no collective traffic, so we parse `compiled.as_text()`.
Collectives inside `while` bodies (lax.scan over layers / loss chunks)
appear ONCE in the text but execute `known_trip_count` times — we build the
computation call graph, propagate trip-count multipliers from ENTRY, and
weight each op's (per-device) result bytes accordingly.
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(%[\w\.\-]+|ENTRY\s+%?[\w\.\-]+)\s*(?:\([^)]*\))?")
_WHILE_RE = re.compile(r"while\(")
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\":{\"n\":\"(\d+)\"")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w\.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> dict:
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_RE.match(line.strip())
            if m and "{" in line:
                name = m.group(1)
                if name.startswith("ENTRY"):
                    name = "ENTRY"
                cur = name
                comps[cur] = []
                continue
        if cur is not None and line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def _multipliers(comps: dict) -> dict:
    """Trip-count multiplier per computation, propagated from ENTRY."""
    mult = {name: 0.0 for name in comps}
    mult["ENTRY"] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(12):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                trip = 1.0
                if _WHILE_RE.search(line):
                    t = _TRIP_RE.search(line)
                    trip = float(t.group(1)) if t else 1.0
                for callee in _CALL_RE.findall(line):
                    new = m * trip
                    if new > mult.get(callee, 0.0):
                        mult[callee] = new
                        changed = True
        if not changed:
            break
    return mult


_NAME_SHAPE_RE = re.compile(r"^\s*(%[\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)")
_DOT_RE = re.compile(r"=\s*([a-z0-9]+\[[\d,]*\])\S*\s+dot\((%[\w\.\-]+),\s*(%[\w\.\-]+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"\((%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\)")


def _shape_dims(shape_str: str) -> tuple[str, list]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _name_shapes(hlo_text: str) -> dict:
    """op name -> full shape string (first definition wins per comp scope;
    shapes are what matter, collisions across comps share the same shape
    text format so approximation is acceptable)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _NAME_SHAPE_RE.match(line)
        if m and m.group(1) not in out:
            out[m.group(1)] = m.group(2)
    return out


def dot_flops(hlo_text: str) -> float:
    """Loop-weighted per-device dot FLOPs: 2 * out_elems * K per dot, where
    K is the product of the lhs contracting dims."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    shapes = _name_shapes(hlo_text)
    total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0) or 1.0
        for line in lines:
            if " dot(" not in f" {line}":
                continue
            dm = _DOT_RE.search(line)
            if not dm:
                continue
            _, out_dims = _shape_dims(dm.group(1))
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            lhs_shape = shapes.get(dm.group(2), "")
            _, lhs_dims = _shape_dims(lhs_shape)
            cm = _LHS_CONTRACT_RE.search(line)
            k = 1
            if cm and lhs_dims:
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
            total += 2.0 * out_elems * k * m
    return total


_SKIP_OPS = (" parameter(", " constant(", " get-tuple-element(", " tuple(",
             " bitcast(", " while(", " after-all(", " partition-id(",
             " iota(")


def hbm_bytes(hlo_text: str) -> float:
    """Loop-weighted per-device HBM traffic estimate: result + operand bytes
    of every top-level op in ENTRY and while bodies (fusion interiors are
    fused: only the fusion's own boundary traffic counts)."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    shapes = _name_shapes(hlo_text)
    # schedulable = ENTRY + while bodies/conditions (reached via body=/condition=)
    schedulable = {"ENTRY"}
    for name, lines in comps.items():
        for line in lines:
            if _WHILE_RE.search(line):
                for attr in ("body", "condition"):
                    mm = re.search(attr + r"=(%[\w\.\-]+)", line)
                    if mm:
                        schedulable.add(mm.group(1))
    total = 0.0
    for name in schedulable:
        lines = comps.get(name, [])
        m = mult.get(name, 0.0) or 1.0
        for line in lines:
            padded = f" {line}"
            if any(s in padded for s in _SKIP_OPS):
                continue
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                continue
            if " dynamic-update-slice(" in padded:
                # in-place: traffic = read+write of the UPDATE slice only
                om = _OPERAND_RE.search(lhs[1])
                if om:
                    ops = [o.strip() for o in om.group(1).split(",")]
                    if len(ops) >= 2:
                        total += 2 * _shape_bytes(shapes.get(ops[1], "")) * m
                continue
            if " dynamic-slice(" in padded:
                total += 2 * _shape_bytes(lhs[1].split("(")[0]) * m
                continue
            b = _shape_bytes(lhs[1].split("(")[0])
            om = _OPERAND_RE.search(lhs[1])
            if om:
                for opn in om.group(1).split(","):
                    b += _shape_bytes(shapes.get(opn.strip(), ""))
            total += b * m
    return total


def collective_bytes(hlo_text: str) -> dict:
    """kind -> {count, bytes, static_count}; bytes are per-device result
    bytes weighted by loop trip counts ("-done" async halves skipped)."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    out = {k: {"count": 0, "bytes": 0.0, "static_count": 0}
           for k in COLLECTIVES}
    for name, lines in comps.items():
        m = mult.get(name, 0.0) or (1.0 if name == "ENTRY" else 0.0)
        if m == 0.0:
            m = 1.0  # unreached comps (conservative)
        for line in lines:
            for kind in COLLECTIVES:
                token = f" {kind}("
                token_start = f" {kind}-start("
                if token in f" {line}" or token_start in f" {line}":
                    if f"{kind}-done" in line:
                        continue
                    lhs = line.split("=", 1)
                    if len(lhs) != 2:
                        continue
                    head = lhs[1].split(kind)[0]
                    b = _shape_bytes(head)
                    out[kind]["static_count"] += 1
                    out[kind]["count"] += int(m)
                    out[kind]["bytes"] += b * m
    return {k: v for k, v in out.items() if v["static_count"]}
