"""K-SDJ spatial analytics on the synthetic LGD workload: runs the
benchmark queries through STREAK and prints plan decisions, SIP pruning and
early-termination behaviour per query (the paper's §5 analysis, live).

    PYTHONPATH=src python examples/spatial_analytics.py [--n 2000]
"""
import argparse
import time

from repro import ExecConfig, StreakEngine
from repro.core.baselines import FullScanEngine
from repro.data import synth_rdf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000, help="entities per class")
    args = ap.parse_args()

    t0 = time.time()
    ds = synth_rdf.make_lgd(n_per_class=args.n, seed=0, block=512)
    tree = ds.store.tree
    print(f"built LGD-like store in {time.time()-t0:.1f}s: "
          f"{ds.store.n_quads} quads, {tree.n_objects} spatial entities, "
          f"S-QuadTree {tree.n_nodes} nodes "
          f"({tree.nbytes()/2**20:.2f} MiB, "
          f"{tree.nbytes()/ds.raw_nbytes*100:.1f}% of raw)\n")

    hdr = (f"{'query':>6s} {'streak':>9s} {'fullscan':>9s} {'speedup':>8s} "
           f"{'plans(N/S)':>10s} {'join rows':>10s} {'early':>6s}")
    print(hdr)
    for qi, q in enumerate(ds.queries):
        eng = StreakEngine(ds.store, ExecConfig(block=512))
        t0 = time.time()
        scores, rows, st = eng.execute(q)
        t_streak = time.time() - t0
        t0 = time.time()
        FullScanEngine(ds.store).execute(q)
        t_full = time.time() - t0
        print(f"    Q{qi+1} {t_streak*1e3:8.1f}ms {t_full*1e3:8.1f}ms "
              f"{t_full/max(t_streak,1e-9):7.1f}x "
              f"{st.plan_n:>5d}/{st.plan_s:<4d} "
              f"{st.driven_rows_after_sip:>10d} "
              f"{str(st.early_terminated):>6s}")


if __name__ == "__main__":
    main()
