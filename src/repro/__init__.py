"""STREAK reproduction: top-k SPARQL with spatial filters on JAX/Pallas.

Stable public surface — everything an application needs to build a store,
configure backends, and run spatial top-k queries:

    from repro import (QuadStore, build_store, StreakEngine, ExecConfig,
                       BackendPolicy, Query, TriplePattern, Relation)

    store = build_store(quads, numeric_predicates=..., geometries=...)
    engine = StreakEngine(store, ExecConfig(policy=BackendPolicy()))
    scores, rows, stats = engine.execute(query)

Subsystem internals (kernels, planner, serving loop, baselines) stay
importable under their module paths (`repro.core.*`, `repro.kernels.*`,
`repro.serve.*`) but are not covered by this surface.
"""
from .core import (BackendPolicy, ExecConfig, ExecStats, FaultPlan,
                   FaultRule, Query, QuadStore, QueryDeadline, Ranking,
                   Relation, ShardedQuadStore, SpatialFilter, StreakEngine,
                   TriplePattern, Var, build_store, shard_store)

__all__ = [
    "BackendPolicy", "ExecConfig", "ExecStats", "FaultPlan", "FaultRule",
    "Query", "QuadStore", "QueryDeadline", "Ranking", "Relation",
    "ShardedQuadStore", "SpatialFilter", "StreakEngine", "TriplePattern",
    "Var", "build_store", "shard_store",
]
