"""S-QuadTree: the soft-schema-aware spatial index (paper §3.1).

Construction is host-side numpy (the paper builds the index in a
pre-processing stage; "zero index creation overhead during query
processing"). The result is a struct-of-arrays tree consumed by the jitted
query path:

- objects are assigned ``(S, Z, I, L)`` ids at the deepest fully-enclosing
  cell (level <= L_MAX) and sorted by id, so *any* subtree's objects are one
  contiguous slice — I-Range lookups are two binary searches;
- every materialized node stores I-Range, E-list, MBR, Bloom filters over
  self/incoming/outgoing characteristic sets, and per-CS cardinalities.

Phase-1 candidate-node search (`candidate_nodes`) and the Z-order cell-list
radius join used by the GNN substrate (`radius_join`) also live here.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import charsets, geometry, ids, morton
from .charsets import BloomBank, NodeCSStats, PreparedKeys, build_node_cs_stats
from .geometry import Extent


# Phase-1 traversal backend: "numpy" is the host level-synchronous frontier
# (`_frontier`, fastest on CPU); "kernel" the fused Pallas descent
# (kernels/tree_descend.py) on TPU and its jitted dense oracle on CPU;
# "interpret" forces the Pallas kernel in interpret mode (tests). "auto"
# resolves once per process: kernel on TPU, numpy otherwise.
DESCEND_BACKENDS = ("auto", "numpy", "kernel", "interpret")
_auto_descend_backend: str | None = None


def resolve_descend_backend(backend: str | None) -> str:
    global _auto_descend_backend
    b = backend or "auto"
    if b not in DESCEND_BACKENDS:
        raise ValueError(f"unknown tree-descend backend {b!r}")
    if b != "auto":
        return b
    if _auto_descend_backend is None:
        import jax  # lazy: keep this module importable without jax
        _auto_descend_backend = ("kernel" if jax.default_backend() == "tpu"
                                 else "numpy")
    return _auto_descend_backend


def csr_gather(starts: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    """Flat indices of the slices [starts_i, starts_i + cnt_i), concatenated.

    The cumsum/repeat per-slice iota: equivalent to
    ``np.concatenate([np.arange(s, s + c) for s, c in zip(starts, cnt)])``
    without the python loop.
    """
    total = int(cnt.sum())
    base = np.repeat(starts - np.concatenate([[0], np.cumsum(cnt)[:-1]]), cnt)
    return base + np.arange(total)


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Vectorized bit_length for non-negative int64 (branchless clz)."""
    g = x.astype(np.uint64)
    out = np.zeros(len(g), dtype=np.int64)
    for s in (32, 16, 8, 4, 2, 1):
        m = (g >> np.uint64(s)) != 0
        out[m] += s
        g[m] >>= np.uint64(s)
    return out + (g != 0)


@dataclasses.dataclass
class PackedEList:
    """Compressed E-list tier: k²-triples-style bit-packed adjacency.

    Each nonempty node's sorted id list is stored as a 64-bit base plus
    gap-encoded deltas, bit-packed at the node's own width (the bit length
    of its largest gap) into one shared uint64 word stream. When the
    tree's sorted `obj_ids` array is supplied at encode time, lists are
    first mapped to their RANKS in that array and the ranks are what gets
    gap-packed (`src` set): every E-list id is an object of the same tree,
    so the rank view is lossless, and rank gaps are positional distances
    bounded by ``bit_length(n_objects)`` bits — leaf lists that mix id
    levels (50+-bit raw-id gaps) shrink to a few bits per entry. Decoding
    is a vectorized word/shift extraction plus a segmented cumsum, then a
    gather through `src` in rank mode, done per node on the gather path
    (`SQuadTree.elist` / `filter_material`); `elist_size` stays on the raw
    CSR offsets so size-only consumers never touch this tier.
    """
    nodes: np.ndarray     # (K,) int32 sorted node indices w/ nonempty lists
    counts: np.ndarray    # (K,) int32 list length per node
    base: np.ndarray      # (K,) int64 first id (or rank, if `src`) per list
    width: np.ndarray     # (K,) uint8 bits per packed gap (1..63)
    bit_off: np.ndarray   # (K,) int64 start bit of each node's gap stream
    words: np.ndarray     # (W,) uint64 packed gaps (+ stitch padding)
    src: np.ndarray | None = None  # shared sorted obj_ids (not owned):
    #                                when set, packed values are ranks into it

    @classmethod
    def encode(cls, offsets: np.ndarray, ids_flat: np.ndarray,
               obj_ids: np.ndarray | None = None) -> "PackedEList":
        counts_all = np.diff(offsets)
        nodes = np.flatnonzero(counts_all).astype(np.int32)
        counts = counts_all[nodes.astype(np.int64)].astype(np.int32)
        k = len(nodes)
        if k == 0:
            return cls(nodes, counts, np.empty(0, np.int64),
                       np.empty(0, np.uint8), np.empty(0, np.int64),
                       np.zeros(1, np.uint64))
        starts = offsets[nodes]
        src = None
        vals = ids_flat
        if obj_ids is not None and len(obj_ids):
            r = np.searchsorted(obj_ids, ids_flat)
            r[r >= len(obj_ids)] = 0
            if np.array_equal(obj_ids[r], ids_flat):
                src, vals = obj_ids, r.astype(np.int64)
        base = vals[starts].astype(np.int64)
        # gaps between consecutive values; each list's first slot is floored
        # to 1 so it can share the per-node max without dominating it (real
        # gaps are >= 1: lists are sorted unique, ranks strictly increase)
        d = np.empty(len(vals), dtype=np.int64)
        d[0] = 1
        d[1:] = vals[1:] - vals[:-1]
        d[starts] = 1
        width = _bit_length(np.maximum.reduceat(d, starts))
        # spatial ids all carry the S bit, so gaps fit well under 2^62
        assert int(width.max()) <= 63, "E-list gap exceeds 63 bits"
        n_gaps = counts - 1
        bits = width * n_gaps
        bit_off = np.concatenate([[0], np.cumsum(bits)[:-1]]).astype(np.int64)
        words = np.zeros(int(bits.sum()) // 64 + 2, dtype=np.uint64)
        total_g = int(n_gaps.sum())
        if total_g:
            seg = np.repeat(np.arange(k), n_gaps)
            pos = csr_gather(starts + 1, n_gaps)
            local = pos - starts[seg] - 1
            p = bit_off[seg] + local * width[seg]
            w = p >> 6
            sh = (p & 63).astype(np.uint64)
            val = d[pos].astype(np.uint64)
            np.bitwise_or.at(words, w, val << sh)
            rs = (np.uint64(64) - sh) & np.uint64(63)
            hi = np.where(sh != 0, val >> rs, np.uint64(0))
            np.bitwise_or.at(words, w + 1, hi)
        return cls(nodes, counts, base, width.astype(np.uint8),
                   bit_off, words, src=src)

    def decode(self, ranks: np.ndarray) -> np.ndarray:
        """Concatenated decoded id lists for node *ranks* (indices into
        `nodes`), each list in its original sorted order."""
        ranks = np.asarray(ranks, dtype=np.int64)
        cnt = self.counts[ranks].astype(np.int64)
        total = int(cnt.sum())
        out = np.empty(total, dtype=np.int64)
        if total == 0:
            return out
        first = np.concatenate([[0], np.cumsum(cnt)[:-1]]).astype(np.int64)
        out[first] = self.base[ranks]
        n_g = cnt - 1
        total_g = int(n_g.sum())
        if total_g:
            seg = np.repeat(np.arange(len(ranks)), n_g)
            local = (np.arange(total_g)
                     - np.repeat(np.cumsum(n_g) - n_g, n_g))
            r = ranks[seg]
            wdt = self.width[r].astype(np.int64)
            p = self.bit_off[r] + local * wdt
            w = p >> 6
            sh = (p & 63).astype(np.uint64)
            rs = (np.uint64(64) - sh) & np.uint64(63)
            v = (self.words[w] >> sh) | np.where(
                sh != 0, self.words[w + 1] << rs, np.uint64(0))
            mask = (np.uint64(1) << wdt.astype(np.uint64)) - np.uint64(1)
            out[np.repeat(first, n_g) + 1 + local] = (v & mask).astype(
                np.int64)
        cs = np.cumsum(out)
        out = cs - np.repeat(cs[first] - out[first], cnt)
        return self.src[out] if self.src is not None else out

    def ranks_of(self, node_idx: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
        """(ranks, positions) for the input node indices with nonempty
        lists: ``ranks[t]`` indexes `nodes`/`counts` and ``positions[t]``
        is the index into `node_idx` it came from, so callers that align
        decoded lists against their input order can re-associate them.
        Nodes with empty E-lists yield no entry (they have no rank) —
        their absence is visible as a gap in ``positions``.
        """
        node_idx = np.asarray(node_idx, dtype=np.int64)
        if not len(self.nodes):
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int64))
        r = np.searchsorted(self.nodes, node_idx)
        r_c = np.minimum(r, len(self.nodes) - 1)
        hit = (self.nodes[r_c] == node_idx) & (r < len(self.nodes))
        return r_c[hit], np.flatnonzero(hit)

    def nbytes(self) -> int:
        # `src` is the tree's own obj_ids array, shared not owned — it is
        # already accounted for in `SQuadTree.nbytes`.
        return (self.nodes.nbytes + self.counts.nbytes + self.base.nbytes
                + self.width.nbytes + self.bit_off.nbytes
                + self.words.nbytes)


def _pad_box_sets(box_sets) -> np.ndarray:
    """Stack ragged per-block box sets into (B, M_max, 4) with NaN padding.

    NaN rows fail every interval comparison in `geometry.boxes_intersect`,
    so padded slots can never contribute a hit — the batched frontier sees
    exactly the real boxes of each block.
    """
    if isinstance(box_sets, np.ndarray):
        return box_sets
    m_max = max((len(b) for b in box_sets), default=0)
    out = np.full((len(box_sets), max(m_max, 1), 4), np.nan)
    for i, b in enumerate(box_sets):
        if len(b):
            out[i, :len(b)] = b
    return out


@dataclasses.dataclass
class SQuadTree:
    extent: Extent
    l_max: int
    # --- node SoA (index 0 is the root; parents precede children) ---
    node_z: np.ndarray          # (N,) int64 z-path at the node's own level
    node_level: np.ndarray      # (N,) int32
    node_parent: np.ndarray     # (N,) int32 (-1 for root)
    node_children: np.ndarray   # (N, 4) int32 (-1 = absent)
    node_cell: np.ndarray       # (N, 4) float64 normalized cell box
    node_mbr: np.ndarray        # (N, 4) float64 union of clipped object MBRs
    irange: np.ndarray          # (N, 2) int64 closed subtree id interval
    n_subtree: np.ndarray       # (N,) int64 objects in subtree (incl. own)
    elist_offsets: np.ndarray   # (N + 1,) int64 CSR offsets into elist_ids
    elist_ids: np.ndarray       # (nnz,) int64 sorted within each node
    bloom_self: BloomBank
    bloom_in: BloomBank
    bloom_out: BloomBank
    cs_stats: NodeCSStats       # self-CS cardinalities per node
    # --- object SoA, sorted by id ---
    obj_ids: np.ndarray         # (M,) int64
    obj_mbr: np.ndarray         # (M, 4) float64 normalized
    obj_entity: np.ndarray      # (M,) int64 original entity key
    entity_to_id: dict          # entity key -> spatial id
    # --- optional compressed E-list tier (replaces elist_ids when set) ---
    packed: PackedEList | None = None
    # --- derived level buckets (computed in __post_init__) ---
    # Nodes are laid out parents-before-children but levels interleave (DFS
    # build order); the CSR below buckets node indices by level so the
    # level-synchronous frontier and the node-selection DP sweep touch each
    # level's nodes with one contiguous gather instead of an O(N) rescan.
    level_order: np.ndarray = dataclasses.field(init=False)    # (N,) int64
    level_offsets: np.ndarray = dataclasses.field(init=False)  # (L + 2,)

    def __post_init__(self):
        levels = self.node_level.astype(np.int64)
        n_levels = int(levels.max()) + 1 if len(levels) else 0
        counts = np.bincount(levels, minlength=n_levels)
        self.level_order = np.argsort(levels, kind="stable").astype(np.int64)
        self.level_offsets = np.concatenate(
            [[0], np.cumsum(counts)]).astype(np.int64)

    # ------------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.node_z)

    @property
    def n_levels(self) -> int:
        return len(self.level_offsets) - 1

    def level_nodes(self, lvl: int) -> np.ndarray:
        """Node indices at `lvl`, in parents-before-children build order."""
        return self.level_order[self.level_offsets[lvl]:
                                self.level_offsets[lvl + 1]]

    @property
    def n_objects(self) -> int:
        return len(self.obj_ids)

    def elist(self, node: int) -> np.ndarray:
        if self.packed is not None:
            ranks, _ = self.packed.ranks_of(np.array([node], dtype=np.int64))
            return (self.packed.decode(ranks) if len(ranks)
                    else np.empty(0, dtype=np.int64))
        a, b = self.elist_offsets[node], self.elist_offsets[node + 1]
        return self.elist_ids[a:b]

    def pack_elists(self) -> "SQuadTree":
        """Switch to the compressed `PackedEList` tier in place (and drop
        the raw id array). Accessors decode per node on the gather path;
        `elist_size` stays on the CSR offsets either way."""
        if self.packed is None and len(self.elist_ids):
            self.packed = PackedEList.encode(self.elist_offsets,
                                             self.elist_ids, self.obj_ids)
            self.elist_ids = np.empty(0, dtype=np.int64)
        return self

    def elist_size(self, node) -> np.ndarray:
        node = np.asarray(node)
        return self.elist_offsets[node + 1] - self.elist_offsets[node]

    def subtree_slice(self, node: int) -> slice:
        lo, hi = self.irange[node]
        a = int(np.searchsorted(self.obj_ids, lo, side="left"))
        b = int(np.searchsorted(self.obj_ids, hi, side="right"))
        return slice(a, b)

    def nbytes(self) -> int:
        total = 0
        for arr in (self.node_z, self.node_level, self.node_parent,
                    self.node_children, self.node_cell, self.node_mbr,
                    self.irange, self.n_subtree, self.elist_offsets,
                    self.elist_ids, self.obj_ids, self.obj_mbr,
                    self.obj_entity):
            total += arr.nbytes
        total += self.bloom_self.nbytes() + self.bloom_in.nbytes()
        total += self.bloom_out.nbytes() + self.cs_stats.nbytes()
        if self.packed is not None:
            total += self.packed.nbytes()
        return total

    # ------------------------------------------------------------------
    # Phase 1: candidate-node search (paper §3.2.1)
    # ------------------------------------------------------------------
    def candidate_nodes(self, driver_boxes, dist_norm: float,
                        driven_cs: np.ndarray, which: str = "self",
                        prepared: PreparedKeys | None = None,
                        probe_backend: str | None = None,
                        descend_backend: str | None = None,
                        cs_path: np.ndarray | None = None) -> np.ndarray:
        """Boolean candidate mask(s): the connected set V per driver block.

        A node survives iff (a) its Bloom filter reports some driven-CS object
        intersecting it, and (b) its MBR expanded by the query distance
        intersects at least one driver-object MBR. The traversal is a
        level-synchronous frontier over the level-bucketed node layout and is
        *batched*: `driver_boxes` may be one block ``(M, 4)`` -> ``(N,)``
        mask, or a batch ``(B, M, 4)`` (or a ragged list of ``(M_i, 4)``
        arrays) -> ``(B, N)`` masks computed in one pass. Bloom-row probes
        are shared across blocks (a node is probed once per level regardless
        of how many blocks' frontiers reached it) and the MBR tests broadcast
        over the whole batch. Results are bit-identical to the looped oracle
        `candidate_nodes_looped`.

        `prepared` hoists the driven-CS key hashing out of the call (see
        `BloomBank.prepare`); `probe_backend` routes the Bloom probes through
        the Pallas `bloom_probe` kernel or the numpy oracle
        (`charsets.PROBE_BACKENDS`).

        Multi-query form (the serving layer): `driven_cs` may be a LIST of
        per-block CS arrays (one per batch row, from different queries), with
        `prepared` an aligned list (or None) and `dist_norm` a scalar or a
        per-block ``(B,)`` array. Blocks whose CS sets are identical share
        one frontier pass (Bloom-probe sharing is only valid within such a
        group); per-block results are bit-identical to separate calls.

        `descend_backend` selects the traversal route (`DESCEND_BACKENDS`):
        "numpy" runs the host frontier; "kernel"/"interpret" run the fused
        device descent, whose per-query root-path Bloom mask may be
        precomputed once via `cs_path_mask` and passed as `cs_path` — an
        ``(N,)`` mask in the shared-CS form, or a list aligned with the
        `driven_cs` list in the multi-query form (rows sharing a CS group
        must carry the same mask; missing/None entries are derived here).
        """
        bank = {"self": self.bloom_self, "in": self.bloom_in,
                "out": self.bloom_out}[which]
        dback = resolve_descend_backend(descend_backend)
        if isinstance(driven_cs, (list, tuple)):
            boxes = _pad_box_sets(driver_boxes)
            n_b = len(boxes)
            if len(driven_cs) != n_b:
                raise ValueError("driven_cs list must match the block batch")
            dist_arr = np.broadcast_to(
                np.asarray(dist_norm, dtype=np.float64), (n_b,))
            prep = (list(prepared) if prepared is not None else [None] * n_b)
            paths = (list(cs_path) if isinstance(cs_path, (list, tuple))
                     else [None] * n_b)
            if len(prep) != n_b or len(paths) != n_b:
                raise ValueError("prepared/cs_path lists must match the batch")
            cs_arrs = [np.asarray(c, dtype=np.int64) for c in driven_cs]
            out = np.zeros((n_b, self.n_nodes), dtype=bool)
            groups: dict[bytes, list[int]] = {}
            for i, c in enumerate(cs_arrs):
                groups.setdefault(c.tobytes(), []).append(i)
            for sel in groups.values():
                si = np.asarray(sel, dtype=np.int64)
                out[si] = self._route(boxes[si], dist_arr[si],
                                      cs_arrs[sel[0]], bank, which,
                                      prep[sel[0]], probe_backend,
                                      dback, paths[sel[0]])
            return out
        single = isinstance(driver_boxes, np.ndarray) and driver_boxes.ndim == 2
        boxes = driver_boxes[None] if single else _pad_box_sets(driver_boxes)
        in_v = self._route(boxes, dist_norm,
                           np.asarray(driven_cs, dtype=np.int64),
                           bank, which, prepared, probe_backend,
                           dback, cs_path)
        return in_v[0] if single else in_v

    def _route(self, boxes: np.ndarray, dist_norm, driven_cs: np.ndarray,
               bank: BloomBank, which: str, prepared, probe_backend,
               descend_backend: str, cs_path) -> np.ndarray:
        """One shared-CS group -> host frontier or fused device descent."""
        if descend_backend == "numpy":
            return self._frontier(boxes, dist_norm, driven_cs, bank,
                                  prepared, probe_backend)
        n_b = len(boxes)
        if not (n_b and len(driven_cs) and boxes.shape[1]):
            return np.zeros((n_b, self.n_nodes), dtype=bool)
        if cs_path is None:
            cs_path = self.cs_path_mask(driven_cs, which=which,
                                        prepared=prepared,
                                        probe_backend=probe_backend)
        return self._descend(boxes, dist_norm, cs_path, descend_backend)

    def _frontier(self, boxes: np.ndarray, dist_norm, driven_cs: np.ndarray,
                  bank: BloomBank, prepared: PreparedKeys | None,
                  probe_backend: str | None) -> np.ndarray:
        """The batched level-synchronous frontier over one shared CS set.

        boxes (B, M, 4) NaN-padded; dist_norm scalar or per-block (B,).
        """
        n_b = len(boxes)
        in_v = np.zeros((n_b, self.n_nodes), dtype=bool)
        if n_b and len(driven_cs) and boxes.shape[1]:
            if prepared is None or prepared.nbits != bank.nbits \
                    or prepared.k != bank.k \
                    or not np.array_equal(prepared.keys, driven_cs):
                prepared = bank.prepare(driven_cs)
            d = (dist_norm if np.ndim(dist_norm) == 0
                 else np.asarray(dist_norm, dtype=np.float64)[:, None])
            expanded = geometry.expand_boxes(boxes, d)          # (B, M, 4)
            # Flat (block, node, box) triple frontier — a simultaneous
            # descent of every block's expanded driver boxes down the tree.
            # Because child MBRs nest inside their parent's (clipped unions
            # over subsets of the parent's objects), a box that misses a
            # node's MBR can never hit a descendant's, so each (block, node)
            # box list shrinks geometrically instead of re-testing all M
            # boxes at every frontier node like the looped BFS does. Runs of
            # equal (block, node) stay contiguous by construction, so
            # per-node reductions are bincount over run ids.
            m = boxes.shape[1]
            tb = np.repeat(np.arange(n_b, dtype=np.int64), m)   # block
            tx = np.tile(np.arange(m, dtype=np.int64), n_b)     # box
            keep = np.isfinite(expanded[tb, tx, 0])  # drop ragged padding
            tb, tx = tb[keep], tx[keep]
            tn = np.zeros(len(tb), dtype=np.int64)              # node (root)
            while len(tb):
                # Bloom-probe each distinct frontier node once, shared by
                # every block whose frontier reached it
                uniq_nodes = np.unique(tn)
                cs_hit = bank.contains_any_batch(uniq_nodes, prepared,
                                                 probe_backend)
                node_cs = cs_hit[np.searchsorted(uniq_nodes, tn)]
                tboxes = expanded[tb, tx]                       # (T, 4)
                hit = node_cs & geometry.boxes_intersect(
                    self.node_mbr[tn], tboxes)
                change = np.empty(len(tb), dtype=bool)
                change[0] = True
                change[1:] = (tb[1:] != tb[:-1]) | (tn[1:] != tn[:-1])
                run_id = np.cumsum(change) - 1
                starts = np.flatnonzero(change)
                ok_run = np.bincount(run_id, weights=hit) > 0
                in_v[tb[starts], tn[starts]] = ok_run
                # descend: surviving (block, node) groups push their
                # MBR-hitting boxes into the children whose cell they touch
                cand = ok_run[run_id] & hit
                if not cand.any():
                    break
                cb, cn, cx = tb[cand], tn[cand], tx[cand]
                cbox = tboxes[cand]
                kids = self.node_children[cn]                   # (C, 4)
                parts = []
                for q in range(4):
                    kq = kids[:, q]
                    v = np.flatnonzero(kq >= 0)
                    if not len(v):
                        continue
                    cell_hit = geometry.boxes_intersect(
                        cbox[v], self.node_cell[kq[v]])
                    vi = v[cell_hit]
                    parts.append((cb[vi], kq[vi], cx[vi]))
                if not parts:
                    break
                tb = np.concatenate([p[0] for p in parts])
                tn = np.concatenate([p[1] for p in parts])
                tx = np.concatenate([p[2] for p in parts])
        return in_v

    def cs_path_mask(self, driven_cs: np.ndarray, which: str = "self",
                     prepared: PreparedKeys | None = None,
                     probe_backend: str | None = None) -> np.ndarray:
        """(N,) bool: the Bloom verdict ANDed down each node's root path.

        The fused descent's whole per-query Bloom contribution. Because
        child MBRs nest inside their parent's exactly (clipped min/max
        unions over subsets of the parent's rows), a driver box hitting a
        node's expanded MBR hits every ancestor's too — so the traversal's
        per-node verdict factorizes as ``geo_hit(n) & cs_path(n)``, with
        this mask the only part that depends on the query's CS set. One
        batch probe over all nodes plus a per-level parent AND (parents
        precede children in the level sweep).
        """
        bank = {"self": self.bloom_self, "in": self.bloom_in,
                "out": self.bloom_out}[which]
        driven_cs = np.asarray(driven_cs, dtype=np.int64)
        n = self.n_nodes
        if n == 0 or len(driven_cs) == 0:
            return np.zeros(n, dtype=bool)
        if prepared is None or prepared.nbits != bank.nbits \
                or prepared.k != bank.k \
                or not np.array_equal(prepared.keys, driven_cs):
            prepared = bank.prepare(driven_cs)
        path = bank.contains_any_batch(np.arange(n, dtype=np.int64),
                                       prepared, probe_backend)
        for lvl in range(1, self.n_levels):
            nodes = self.level_nodes(lvl)
            path[nodes] &= path[self.node_parent[nodes]]
        return path

    def _node_key_planes(self) -> np.ndarray:
        """Cached (4, N) int64 sort-key planes of the node MBRs (rows
        x0, y0, x2, y3) for the fused descent — the tree is immutable, so
        the f64 -> key encoding happens once per tree."""
        keys = getattr(self, "_node_mbr_keys", None)
        if keys is None:
            from ..kernels import ops  # lazy: keep module importable sans jax
            keys = ops.f64_sort_keys(np.ascontiguousarray(self.node_mbr.T))
            self._node_mbr_keys = keys
        return keys

    def _descend(self, boxes: np.ndarray, dist_norm,
                 cs_path: np.ndarray, backend: str) -> np.ndarray:
        """The fused device pass: one `ops.tree_descend` call replaces the
        per-level frontier. boxes (B, M, 4) NaN-padded; bit-identical to
        `_frontier` / `candidate_nodes_looped` (the box expansion and the
        f64 -> int64 key map are exact, so the kernel's 32-bit plane
        compares reproduce the host's f64 interval tests bit-for-bit)."""
        from ..kernels import ops
        d = (dist_norm if np.ndim(dist_norm) == 0
             else np.asarray(dist_norm, dtype=np.float64)[:, None])
        expanded = geometry.expand_boxes(boxes, d)          # (B, M, 4)
        keys = ops.f64_sort_keys(expanded)
        pad = ~np.isfinite(boxes[..., 0])                   # ragged padding
        if pad.any():
            keys[pad] = ops.DESCEND_PAD_BOX
        return ops.tree_descend(self._node_key_planes(), cs_path, keys,
                                backend=backend)

    def candidate_nodes_looped(self, driver_boxes: np.ndarray,
                               dist_norm: float, driven_cs: np.ndarray,
                               which: str = "self") -> np.ndarray:
        """Per-block breadth-first oracle for `candidate_nodes` (kept for
        cross-checking the batched frontier; same pruning, python BFS)."""
        bank = {"self": self.bloom_self, "in": self.bloom_in,
                "out": self.bloom_out}[which]
        driven_cs = np.asarray(driven_cs, dtype=np.int64)
        in_v = np.zeros(self.n_nodes, dtype=bool)
        if len(driver_boxes) == 0 or len(driven_cs) == 0:
            return in_v
        frontier = np.array([0], dtype=np.int64)
        expanded = geometry.expand_boxes(driver_boxes, dist_norm)
        while len(frontier):
            # (F, C) bloom probes -> any CS hit per node
            fi = np.repeat(frontier, len(driven_cs))
            keys = np.tile(driven_cs, len(frontier))
            cs_hit = bank.contains(fi, keys).reshape(len(frontier), -1).any(axis=1)
            # (F, B) MBR-vs-driver test -> any driver overlap per node
            mbr = self.node_mbr[frontier]
            geo_hit = geometry.boxes_intersect(
                mbr[:, None, :], expanded[None, :, :]).any(axis=1)
            ok = cs_hit & geo_hit
            in_v[frontier[ok]] = True
            kids = self.node_children[frontier[ok]].ravel()
            frontier = kids[kids >= 0]
        return in_v

    # ------------------------------------------------------------------
    # SIP filter material: id intervals + explicit ids for a node set
    # ------------------------------------------------------------------
    def filter_material(self, v_star: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
        """(intervals (K,2) int64, explicit ids sorted) for SIP filtering.

        Driven-side entries survive iff their spatial id falls in one of the
        I-Range intervals or equals one of the E-list ids (paper §3.2.2).
        """
        v_star = np.asarray(v_star, dtype=np.int64)
        intervals = self.irange[v_star] if len(v_star) else np.zeros((0, 2), np.int64)
        starts = self.elist_offsets[v_star]
        cnt = self.elist_offsets[v_star + 1] - starts
        if cnt.sum() == 0:
            return intervals, np.empty(0, dtype=np.int64)
        if self.packed is not None:
            explicit = np.unique(
                self.packed.decode(self.packed.ranks_of(v_star)[0]))
        else:
            explicit = np.unique(self.elist_ids[csr_gather(starts, cnt)])
        return intervals, explicit


# ----------------------------------------------------------------------------
# Construction
# ----------------------------------------------------------------------------

def _assign_ids(boxes_norm: np.ndarray, l_max: int
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deepest-enclosing-node assignment -> (id, zpath_own_level, level)."""
    lo = morton.encode_points(boxes_norm[:, 0:2], l_max)
    hi = morton.encode_points(boxes_norm[:, 2:4], l_max)
    level = morton.common_level(lo, hi, l_max)
    zpath = np.asarray(lo, dtype=np.int64) >> (2 * (l_max - level))
    # local ids: running counter within each (zpath, level) node
    order = np.lexsort((np.arange(len(level)), zpath, level))
    local = np.zeros(len(level), dtype=np.int64)
    z_s, l_s = zpath[order], level[order]
    same = np.zeros(len(level), dtype=np.int64)
    if len(level) > 1:
        same_prev = (z_s[1:] == z_s[:-1]) & (l_s[1:] == l_s[:-1])
        run = np.zeros(len(level), dtype=np.int64)
        # running count within equal runs
        idx_change = np.flatnonzero(~same_prev) + 1
        starts = np.concatenate([[0], idx_change])
        lengths = np.diff(np.concatenate([starts, [len(level)]]))
        run = np.arange(len(level)) - np.repeat(starts, lengths)
        same = run
    local[order] = same
    oid = ids.encode(zpath, level, local)
    return oid, zpath, level


@dataclasses.dataclass
class _BuildNode:
    z: int
    level: int
    parent: int
    elist: np.ndarray  # ids of ancestor-assigned objects overlapping this cell


def build(entity_keys: np.ndarray,
          boxes_world: np.ndarray,
          cs_self: np.ndarray,
          cs_in: tuple[np.ndarray, np.ndarray] | None = None,
          cs_out: tuple[np.ndarray, np.ndarray] | None = None,
          extent: Extent | None = None,
          l_max: int = ids.L_MAX,
          leaf_capacity: int = 64,
          bloom_words: int = 8,
          bloom_k: int = 3,
          oids: np.ndarray | None = None,
          boxes_normalized: bool = False,
          compressed: bool = False) -> SQuadTree:
    """Build the S-QuadTree over spatial entities.

    cs_in / cs_out are CSR pairs ``(offsets, cs_ids)`` aligned to
    ``entity_keys`` giving incoming/outgoing characteristic sets per entity.

    ``oids`` supplies precomputed spatial ids aligned to ``entity_keys``
    (with ``boxes_normalized=True`` and an explicit ``extent``): the shard
    builder uses this to keep GLOBAL ids in shard-local trees — re-running
    `_assign_ids` over a shard's subset would restart the per-(zpath, level)
    local counters and diverge from the single-host assignment.
    ``compressed`` packs the E-list tier (`pack_elists`) before returning.
    """
    assert l_max <= ids.L_MAX
    entity_keys = np.asarray(entity_keys, dtype=np.int64)
    boxes_world = np.asarray(boxes_world, dtype=np.float64)
    cs_self = np.asarray(cs_self, dtype=np.int64)
    m = len(entity_keys)
    if extent is None:
        assert not boxes_normalized, "normalized boxes need an explicit extent"
        extent = Extent.of(boxes_world)
    boxes = boxes_world if boxes_normalized else extent.normalize(boxes_world)

    if oids is None:
        oid, zpath, level = _assign_ids(boxes, l_max)
    else:
        oid = np.asarray(oids, dtype=np.int64)
        _, zpath, level, _ = ids.decode(oid)
    order = np.argsort(oid, kind="stable")
    oid, zpath, level = oid[order], zpath[order], level[order]
    boxes, entity_keys, cs_self = boxes[order], entity_keys[order], cs_self[order]
    inv = {int(k): int(i) for k, i in zip(entity_keys, oid)}

    orig_row = order  # post-sort position -> original row

    # ---- top-down materialization -------------------------------------
    nodes: list[_BuildNode] = []
    children_lists: list[list[int]] = []
    node_index: dict[tuple[int, int], int] = {}

    def subtree_slice(z: int, lvl: int) -> slice:
        lo, hi = ids.subtree_interval(np.int64(z), np.int64(lvl))
        return slice(int(np.searchsorted(oid, lo, "left")),
                     int(np.searchsorted(oid, hi, "right")))

    def own_slice(z: int, lvl: int) -> slice:
        lo, hi = ids.node_own_interval(np.int64(z), np.int64(lvl))
        return slice(int(np.searchsorted(oid, lo, "left")),
                     int(np.searchsorted(oid, hi, "right")))

    def cell_box(z: int, lvl: int) -> np.ndarray:
        cx, cy = morton.deinterleave2(np.uint64(z))
        size = 1.0 / (1 << lvl)
        x0, y0 = float(cx) * size, float(cy) * size
        return np.array([x0, y0, x0 + size, y0 + size])

    stack = [(_BuildNode(0, 0, -1, np.empty(0, dtype=np.int64)))]
    while stack:
        bn = stack.pop()
        my_idx = len(nodes)
        nodes.append(bn)
        children_lists.append([-1, -1, -1, -1])
        node_index[(bn.z, bn.level)] = my_idx
        if bn.parent >= 0:
            quad = bn.z & 3
            children_lists[bn.parent][quad] = my_idx
        ss = subtree_slice(bn.z, bn.level)
        n_sub = ss.stop - ss.start
        osl = own_slice(bn.z, bn.level)
        n_own = osl.stop - osl.start
        if bn.level >= l_max or n_sub <= max(leaf_capacity, n_own):
            continue  # leaf: everything below stays in this node's interval
        # split: own (straddler) objects propagate into overlapping children
        own_ids = oid[osl]
        own_boxes = boxes[osl]
        parent_elist_ids = bn.elist
        if len(parent_elist_ids):
            el_rows = np.searchsorted(oid, parent_elist_ids)
            el_boxes = boxes[el_rows]
            push_ids = np.concatenate([own_ids, parent_elist_ids])
            push_boxes = np.concatenate([own_boxes, el_boxes], axis=0)
        else:
            push_ids, push_boxes = own_ids, own_boxes
        for quad in range(4):
            cz = (bn.z << 2) | quad
            csl = subtree_slice(cz, bn.level + 1)
            cbox = cell_box(cz, bn.level + 1)
            if len(push_ids):
                hit = geometry.boxes_intersect(push_boxes, cbox[None, :])
                child_el = np.sort(push_ids[hit])
            else:
                child_el = np.empty(0, dtype=np.int64)
            if (csl.stop - csl.start) == 0 and len(child_el) == 0:
                continue  # empty quadrant: not materialized
            stack.append(_BuildNode(cz, bn.level + 1, my_idx, child_el))

    n = len(nodes)
    node_z = np.array([b.z for b in nodes], dtype=np.int64)
    node_level = np.array([b.level for b in nodes], dtype=np.int32)
    node_parent = np.array([b.parent for b in nodes], dtype=np.int32)
    node_children = np.array(children_lists, dtype=np.int32).reshape(n, 4)
    node_cell = np.stack([cell_box(b.z, b.level) for b in nodes])
    lo, hi = ids.subtree_interval(node_z, node_level.astype(np.int64))
    irange = np.stack([lo, hi], axis=1)

    # per-node intersecting objects = subtree slice + elist
    elist_offsets = np.zeros(n + 1, dtype=np.int64)
    elist_parts = []
    node_mbr = np.zeros((n, 4))
    n_subtree = np.zeros(n, dtype=np.int64)
    bloom_self = BloomBank.empty(n, bloom_words, bloom_k)
    bloom_in = BloomBank.empty(n, bloom_words, bloom_k)
    bloom_out = BloomBank.empty(n, bloom_words, bloom_k)
    stat_nodes, stat_cs = [], []

    in_off, in_vals = (cs_in if cs_in is not None
                       else (np.zeros(m + 1, dtype=np.int64), np.empty(0, np.int64)))
    out_off, out_vals = (cs_out if cs_out is not None
                         else (np.zeros(m + 1, dtype=np.int64), np.empty(0, np.int64)))
    # map post-sort rows back to original rows for the CSR lookups
    row_of_orig = np.empty(m, dtype=np.int64)
    row_of_orig[order] = np.arange(m)

    for i, bn in enumerate(nodes):
        ss = subtree_slice(bn.z, bn.level)
        n_subtree[i] = ss.stop - ss.start
        elist_offsets[i + 1] = len(bn.elist)
        elist_parts.append(bn.elist)
        rows = np.arange(ss.start, ss.stop)
        if len(bn.elist):
            rows = np.concatenate([rows, np.searchsorted(oid, bn.elist)])
        if len(rows) == 0:
            node_mbr[i] = node_cell[i]
            continue
        clipped = geometry.clip_boxes(boxes[rows], node_cell[i])
        node_mbr[i] = geometry.union_boxes(clipped)
        cs_here = cs_self[rows]
        bloom_self.add(np.full(len(rows), i), cs_here)
        stat_nodes.append(np.full(len(rows), i, dtype=np.int64))
        stat_cs.append(cs_here)
        orig = orig_row[rows]
        ins = np.concatenate([in_vals[in_off[r]:in_off[r + 1]] for r in orig]) \
            if cs_in is not None else np.empty(0, np.int64)
        outs = np.concatenate([out_vals[out_off[r]:out_off[r + 1]] for r in orig]) \
            if cs_out is not None else np.empty(0, np.int64)
        if len(ins):
            bloom_in.add(np.full(len(ins), i), ins)
        if len(outs):
            bloom_out.add(np.full(len(outs), i), outs)

    elist_offsets = np.cumsum(elist_offsets)
    elist_ids = (np.concatenate(elist_parts) if elist_parts
                 else np.empty(0, dtype=np.int64))
    cs_stats = build_node_cs_stats(
        np.concatenate(stat_nodes) if stat_nodes else np.empty(0, np.int64),
        np.concatenate(stat_cs) if stat_cs else np.empty(0, np.int64), n)

    tree = SQuadTree(
        extent=extent, l_max=l_max,
        node_z=node_z, node_level=node_level, node_parent=node_parent,
        node_children=node_children, node_cell=node_cell, node_mbr=node_mbr,
        irange=irange, n_subtree=n_subtree,
        elist_offsets=elist_offsets, elist_ids=elist_ids,
        bloom_self=bloom_self, bloom_in=bloom_in, bloom_out=bloom_out,
        cs_stats=cs_stats,
        obj_ids=oid, obj_mbr=boxes, obj_entity=entity_keys,
        entity_to_id=inv,
    )
    return tree.pack_elists() if compressed else tree


# ----------------------------------------------------------------------------
# Z-order cell-list radius join (GNN / molecular neighbor lists)
# ----------------------------------------------------------------------------

def radius_join(points_a: np.ndarray, points_b: np.ndarray, radius: float,
                include_self: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """All pairs (i, j) with ||a_i - b_j|| <= radius, via Z-order cell lists.

    This is the paper's distance join specialized to point sets; it is the
    substrate for NequIP cutoff graphs and GraphCast grid<->mesh edges
    (DESIGN.md §Arch-applicability). O(n) cells instead of O(n^2) pairs.
    """
    pa = np.asarray(points_a, dtype=np.float64)
    pb = np.asarray(points_b, dtype=np.float64)
    both = np.concatenate([pa, pb], axis=0)
    ext = Extent.of(geometry.point_boxes(both))
    na = ext.normalize(geometry.point_boxes(pa))[:, :2]
    nb = ext.normalize(geometry.point_boxes(pb))[:, :2]
    # normalization is anisotropic (x / width, y / height): a radius-length
    # offset spans up to radius / min(width, height) normalized units, and
    # the ±1-cell neighborhood is only complete when one cell covers that
    # (radius / max undersizes cells on the narrower axis and drops
    # boundary pairs — caught by the differential query fuzzer)
    r_norm = radius / min(ext.width, ext.height)
    level = int(np.clip(np.floor(-np.log2(max(r_norm, 1e-9))), 0, 16))
    cell_b = morton.cell_of(nb, level)
    nside = 1 << level
    key_b = cell_b[:, 0] * nside + cell_b[:, 1]
    order_b = np.argsort(key_b, kind="stable")
    key_sorted = key_b[order_b]
    cell_a = morton.cell_of(na, level)
    out_i, out_j = [], []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            cx = np.clip(cell_a[:, 0] + dx, 0, nside - 1)
            cy = np.clip(cell_a[:, 1] + dy, 0, nside - 1)
            keys = cx * nside + cy
            lo = np.searchsorted(key_sorted, keys, "left")
            hi = np.searchsorted(key_sorted, keys, "right")
            cnt = hi - lo
            if cnt.sum() == 0:
                continue
            ii = np.repeat(np.arange(len(pa)), cnt)
            jj = order_b[csr_gather(lo, cnt)]
            d = np.sqrt(((pa[ii] - pb[jj]) ** 2).sum(axis=1))
            keep = d <= radius
            if not include_self and len(pa) == len(pb):
                keep = keep & (ii != jj)
            out_i.append(ii[keep])
            out_j.append(jj[keep])
    if not out_i:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    i = np.concatenate(out_i)
    j = np.concatenate(out_j)
    # dedupe (same pair can appear via clipped neighbor cells at the border)
    key = i * np.int64(len(pb)) + j
    _, uniq_idx = np.unique(key, return_index=True)
    return i[uniq_idx], j[uniq_idx]
