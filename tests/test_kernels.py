"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import block_scan as bs
from repro.kernels import bloom_probe as bp
from repro.kernels import distance_join as dj
from repro.kernels import flash_attention as fa
from repro.kernels import fused_topk_join as ftj
from repro.kernels import geom_refine as gr
from repro.kernels import morton_kernel as mk
from repro.kernels import ops, ref


def _boxes(rng, n):
    pts = rng.random((n, 2)).astype(np.float32)
    wh = rng.random((n, 2)).astype(np.float32) * 0.05
    return np.concatenate([pts, pts + wh], axis=1)


# --------------------------------------------------------- distance join ---
@pytest.mark.parametrize("m,n", [(8, 8), (100, 260), (256, 256), (300, 513)])
def test_distance_join_matches_ref(m, n):
    rng = np.random.default_rng(0)
    a, b = _boxes(rng, m), _boxes(rng, n)
    got = dj.distance_join(jnp.asarray(a), jnp.asarray(b),
                           bm=128, bn=128, interpret=True)
    want = ref.distance_join_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_distance_join_agrees_with_engine_geometry():
    from repro.core import geometry
    rng = np.random.default_rng(1)
    a, b = _boxes(rng, 64), _boxes(rng, 64)
    want = geometry.box_min_dist(a[:, None, :].astype(np.float64),
                                 b[None, :, :].astype(np.float64))
    got = dj.distance_join(jnp.asarray(a), jnp.asarray(b),
                           bm=64, bn=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------- fused top-k join -----
def _fused_case(m, n, k, theta, dist, seed=11, bm=128, bn=128):
    rng = np.random.default_rng(seed)
    a, b = _boxes(rng, m), _boxes(rng, n)
    dk = rng.random(m).astype(np.float32)
    vk = rng.random(n).astype(np.float32)
    got = ftj.fused_topk_join(jnp.asarray(a), jnp.asarray(b),
                              jnp.asarray(dk), jnp.asarray(vk),
                              dist, theta, k=k, bm=bm, bn=bn, interpret=True)
    want = ref.fused_topk_join_ref(jnp.asarray(a), jnp.asarray(b),
                                   jnp.asarray(dk), jnp.asarray(vk),
                                   dist, theta, k)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


@pytest.mark.parametrize("m,n", [(8, 8), (100, 260), (256, 256), (300, 513)])
def test_fused_topk_join_matches_ref_tile_boundaries(m, n):
    """M, N not multiples of bm/bn: padding must never surface."""
    (gs, gi, gc), (ws, wi, wc) = _fused_case(m, n, k=8, theta=-np.inf,
                                             dist=0.15)
    np.testing.assert_array_equal(gc, wc)
    np.testing.assert_allclose(gs, ws, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(gi, wi)


def test_fused_topk_join_k_exceeds_survivors():
    """k wider than any row's survivor set: -inf/-1 padding, exact counts."""
    (gs, gi, gc), (ws, wi, wc) = _fused_case(64, 64, k=200, theta=-np.inf,
                                             dist=0.1)
    assert gc.max() < 200              # nothing overflows a 200-wide partial
    np.testing.assert_array_equal(gc, wc)
    np.testing.assert_array_equal(gi, wi)
    padded = gs == -np.inf
    assert (gi[padded] == -1).all()
    # every row's populated prefix length equals its survivor count
    np.testing.assert_array_equal((~padded).sum(axis=1), gc)


@pytest.mark.parametrize("theta", [-np.inf, 0.9, 1.6, np.inf])
def test_fused_topk_join_theta_prunes(theta):
    """θ = -inf keeps every in-distance pair; tighter θ only removes."""
    (gs, gi, gc), (ws, wi, wc) = _fused_case(100, 150, k=16, theta=theta,
                                             dist=0.2)
    np.testing.assert_array_equal(gc, wc)
    np.testing.assert_allclose(gs, ws, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(gi, wi)
    if theta == np.inf:
        assert gc.sum() == 0 and (gi == -1).all()
    finite = gs[gs > -np.inf]
    assert (finite > theta).all() if np.isfinite(theta) else True


def test_fused_counts_signal_overflow_exactly():
    """counts > k marks rows whose survivors exceed the partial width."""
    (gs, gi, gc), (_, _, wc) = _fused_case(60, 500, k=4, theta=-np.inf,
                                           dist=0.5)
    np.testing.assert_array_equal(gc, wc)
    assert (gc > 4).any()              # wide dist: overflow must occur
    # even overflowed rows report their k best pairs correctly
    rng = np.random.default_rng(11)
    a, b = _boxes(rng, 60), _boxes(rng, 500)
    dk = rng.random(60).astype(np.float32)
    vk = rng.random(500).astype(np.float32)
    d = np.asarray(ref.distance_join_ref(jnp.asarray(a), jnp.asarray(b)))
    bound = np.where(d <= 0.5, dk[:, None] + vk[None, :], -np.inf)
    want_best = -np.sort(-bound, axis=1)[:, :4]
    np.testing.assert_allclose(gs, want_best, rtol=1e-6, atol=1e-6)


def test_fused_stream_join_pairs_equal_dense_backends():
    """fused backend candidate pairs == numpy backend == kernel backend."""
    from repro.core import spatial_join
    rng = np.random.default_rng(12)
    a, b = _boxes(rng, 90), _boxes(rng, 333)
    for dist in (0.02, 0.15):
        ref_pairs = spatial_join.mbr_distance_join(
            a.astype(np.float64), b.astype(np.float64), dist, "numpy")
        krn_pairs = spatial_join.mbr_distance_join(
            a.astype(np.float64), b.astype(np.float64), dist, "kernel")
        fus_pairs = spatial_join.mbr_distance_join(
            a.astype(np.float64), b.astype(np.float64), dist, "fused")
        np.testing.assert_array_equal(ref_pairs[0], krn_pairs[0])
        np.testing.assert_array_equal(ref_pairs[1], krn_pairs[1])
        np.testing.assert_array_equal(ref_pairs[0], fus_pairs[0])
        np.testing.assert_array_equal(ref_pairs[1], fus_pairs[1])


def test_fused_stream_join_theta_tightening_only_prunes():
    """A θ that tightens between batches must never drop a winning pair."""
    from repro.core import spatial_join
    from repro.core.topk import TopK
    from repro.core.join import Relation
    rng = np.random.default_rng(13)
    m, n, k = 80, 400, 10
    a, b = _boxes(rng, m), _boxes(rng, n)
    dk = rng.random(m); vk = rng.random(n)
    dist = 0.3
    # oracle: global top-k pair bounds among in-distance pairs
    d = np.asarray(ref.distance_join_ref(jnp.asarray(a), jnp.asarray(b)))
    bound = np.where(d <= dist, dk[:, None] + vk[None, :], -np.inf)
    want = np.sort(bound.ravel())[::-1][:k]
    tk = TopK(k=k)
    for pi, pj in spatial_join.fused_stream_join(
            a.astype(np.float64), b.astype(np.float64), dk, vk, dist, k=k,
            theta_fn=lambda: tk.theta, batch_cols=64):
        s = dk[pi] + vk[pj]
        tk.push(s, Relation({"i": pi, "j": pj}))
    got, _ = tk.results()
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_fused_topk_pairs_two_level_merge_matches_dense():
    """Batch partials merged via topk.merge_row_partials == dense row top-k."""
    from repro.core import spatial_join
    rng = np.random.default_rng(14)
    m, n, k = 70, 300, 6
    a, b = _boxes(rng, m), _boxes(rng, n)
    dk = rng.random(m); vk = rng.random(n)
    dist = 0.25
    gs, gi = spatial_join.fused_topk_pairs(
        a.astype(np.float64), b.astype(np.float64), dk, vk, dist, k=k,
        batch_cols=48)
    d = np.asarray(ref.distance_join_ref(jnp.asarray(a), jnp.asarray(b)))
    bound = np.where(
        d <= dist,
        dk.astype(np.float32)[:, None] + vk.astype(np.float32)[None, :],
        -np.inf)
    want = -np.sort(-bound, axis=1)[:, :k]
    np.testing.assert_allclose(gs, want, rtol=1e-6, atol=1e-6)
    rows = np.arange(m)[:, None]
    picked = np.where(gi >= 0, bound[rows, np.maximum(gi, 0)], -np.inf)
    np.testing.assert_allclose(picked, want, rtol=1e-6, atol=1e-6)


# ------------------------------------------------- bucketed geometry refine --
def _coord_planes(rng, b, w, dims):
    return tuple(rng.uniform(-1, 1, (b, w)).astype(np.float32)
                 for _ in range(dims))


@pytest.mark.parametrize("m_pad,n_pad", [(1, 1), (4, 8), (32, 32), (8, 128)])
@pytest.mark.parametrize("dims", [2, 3])
def test_bucketed_min_core_matches_ref(m_pad, n_pad, dims):
    """B not a bb multiple: padded rows must never surface."""
    rng = np.random.default_rng(20)
    ap = _coord_planes(rng, 70, m_pad, dims)
    bp_ = _coord_planes(rng, 70, n_pad, dims)
    got = gr.bucketed_min_core(tuple(jnp.asarray(p) for p in ap),
                               tuple(jnp.asarray(p) for p in bp_),
                               bb=32, interpret=True)
    want = ref.bucketed_min_core_ref(tuple(jnp.asarray(p) for p in ap),
                                     tuple(jnp.asarray(p) for p in bp_))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("m_pad,n_pad", [(1, 3), (5, 8), (32, 64)])
@pytest.mark.parametrize("dims", [2, 3])
def test_bucketed_min_core_host_twin_matches_ref(m_pad, n_pad, dims):
    """The CPU loop twin (the engine's dispatch target) == dense oracle."""
    rng = np.random.default_rng(22)
    ap = _coord_planes(rng, 53, m_pad, dims)
    bp_ = _coord_planes(rng, 53, n_pad, dims)
    got = gr.bucketed_min_core_host(tuple(jnp.asarray(p) for p in ap),
                                    tuple(jnp.asarray(p) for p in bp_))
    want = ref.bucketed_min_core_ref(tuple(jnp.asarray(p) for p in ap),
                                     tuple(jnp.asarray(p) for p in bp_))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("metric", ["euclid", "haversine"])
def test_bucketed_min_core_agrees_with_engine_geometry(metric):
    """Pool planes -> kernel core -> distance ~= the f64 primitives."""
    from repro.core import geometry, spatial_join
    from repro.core.store import GeomPool
    rng = np.random.default_rng(21)
    n = 40
    pts = np.stack([rng.uniform(-170, 170, 2 * n),
                    rng.uniform(-85, 85, 2 * n)], axis=-1).astype(np.float32)
    pool = GeomPool.from_lists(pts[:, None, :])   # one point per row
    planes = (pool.planes3d() if metric == "haversine" else pool.planes2d())
    ia = np.arange(n)[:, None]           # (n, 1): single-point geometries
    ib = np.arange(n, 2 * n)[:, None]
    core = np.asarray(ops.bucketed_min_core(
        tuple(p[ia] for p in planes), tuple(p[ib] for p in planes),
        interpret=True))
    got = spatial_join.core_to_dist(core, metric)
    pa, pb = pts[:n].astype(np.float64), pts[n:].astype(np.float64)
    fn = geometry.euclid_dist if metric == "euclid" else geometry.haversine_km
    want = fn(pa, pb)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ bloom probe ---
@pytest.mark.parametrize("nb,w,k", [(64, 8, 3), (1000, 16, 4), (2048, 8, 2)])
def test_bloom_probe_matches_ref_and_numpy(nb, w, k):
    from repro.core.charsets import BloomBank
    rng = np.random.default_rng(2)
    bank = BloomBank.empty(8, words=w, k=k)
    ins_keys = rng.integers(0, 1 << 62, size=200, dtype=np.int64)
    ins_f = rng.integers(0, 8, size=200, dtype=np.int64)
    bank.add(ins_f, ins_keys)
    probe_keys = np.concatenate([ins_keys[:nb // 2],
                                 rng.integers(0, 1 << 62, size=nb - nb // 2,
                                              dtype=np.int64)])[:nb]
    probe_f = np.concatenate([ins_f[:nb // 2],
                              rng.integers(0, 8, size=nb - nb // 2,
                                           dtype=np.int64)])[:nb]
    want_np = bank.contains(probe_f, probe_keys)
    rows = jnp.asarray(bank.bits[probe_f])
    u = probe_keys.view(np.uint64)
    lo = jnp.asarray((u & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32))
    hi = jnp.asarray((u >> np.uint64(32)).astype(np.uint32).view(np.int32))
    want_ref = np.asarray(ref.bloom_probe_ref(rows, lo, hi, k))
    got = np.asarray(bp.bloom_probe(rows, lo, hi, k=k, bb=256,
                                    interpret=True)) == 1
    np.testing.assert_array_equal(want_np, want_ref)
    np.testing.assert_array_equal(got, want_ref)


# -------------------------------------------------------------- block scan --
@pytest.mark.parametrize("nb,bsz", [(4, 128), (16, 1024), (1, 256)])
@pytest.mark.parametrize("theta", [-1e30, 0.5, 2.0])
def test_block_scan_matches_ref(nb, bsz, theta):
    rng = np.random.default_rng(3)
    scores = rng.normal(0.5, 0.5, size=(nb, bsz)).astype(np.float32)
    g_max, g_cnt, g_mask = bs.block_scan(jnp.asarray(scores), theta,
                                         interpret=True)
    w_max, w_cnt, w_mask = ref.block_scan_ref(jnp.asarray(scores), theta)
    np.testing.assert_allclose(np.asarray(g_max), np.asarray(w_max), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(g_cnt), np.asarray(w_cnt))
    np.testing.assert_array_equal(np.asarray(g_mask), np.asarray(w_mask))


# ------------------------------------------------------------------ morton --
@pytest.mark.parametrize("n", [100, 1024, 5000])
def test_morton_kernel_matches_ref_and_numpy(n):
    from repro.core import morton
    rng = np.random.default_rng(4)
    cx = rng.integers(0, 1 << 16, size=n).astype(np.int32)
    cy = rng.integers(0, 1 << 16, size=n).astype(np.int32)
    got = np.asarray(mk.morton_encode(jnp.asarray(cx), jnp.asarray(cy),
                                      interpret=True))
    want = np.asarray(ref.morton_ref(jnp.asarray(cx), jnp.asarray(cy)))
    want_np = morton.interleave2(cx.astype(np.int64), cy.astype(np.int64))
    np.testing.assert_array_equal(got, want)
    # int32 codes can use the sign bit for 16-bit inputs: compare unsigned
    np.testing.assert_array_equal(got.view(np.uint32).astype(np.uint64),
                                  want_np.astype(np.uint64))


# -------------------------------------------------------- flash attention ---
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 128, 64),     # MHA
    (1, 4, 2, 128, 64),     # GQA group 2
    (2, 8, 1, 256, 32),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal):
    rng = np.random.default_rng(5)
    q = rng.normal(size=(b, hq, s, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    got = fa.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             causal=causal, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype=jnp.bfloat16)
    got = fa.flash_attention(q, k, v, causal=True, bq=64, bk=64,
                             interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------- ops dispatch layer --
def test_ops_mask_matches_engine_backend():
    rng = np.random.default_rng(7)
    a, b = _boxes(rng, 40), _boxes(rng, 50)
    mask_k = np.asarray(ops.distance_join_mask(a, b, 0.05, interpret=True))
    from repro.core import geometry
    d = geometry.box_min_dist(a[:, None, :].astype(np.float64),
                              b[None, :, :].astype(np.float64))
    np.testing.assert_array_equal(mask_k, d <= 0.05)
