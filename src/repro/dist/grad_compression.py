"""Int8 gradient compression with error feedback.

The cross-pod all-reduce in the multi-pod mesh (launch/mesh.py) moves full
f32 gradients; linear-scale int8 quantization cuts that traffic 4x. Plain
quantization biases the update, so `ef_compress` carries the quantization
residual forward (error feedback): the *accumulated* decompressed sum tracks
the accumulated true sum, which is the property the optimizer needs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def compress(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """g (f32) -> (codes int8, scale f32 scalar): codes * scale ~= g."""
    scale = jnp.max(jnp.abs(g)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(g / safe), -127, 127).astype(jnp.int8)
    return codes, scale


@jax.jit
def decompress(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale


@jax.jit
def ef_compress(g: jnp.ndarray, err: jnp.ndarray
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression step.

    Compresses g + carried error; the new residual (what quantization lost
    this step) is returned to be added to the next step's gradient.
    """
    target = g + err
    codes, scale = compress(target)
    new_err = target - decompress(codes, scale)
    return codes, scale, new_err


def init_error_state(params):
    """Zero residuals shaped like the parameters (trainer hook)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def tree_ef_compress_roundtrip(grads, err_state):
    """Compress+decompress every gradient leaf with error feedback.

    Models what the cross-pod all-reduce sees (quantize, transfer, restore);
    returns (decompressed grads, new error state) mirroring the input trees.
    """
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = jax.tree.leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(leaves_g, leaves_e):
        codes, scale, new_e = ef_compress(g, e)
        out_g.append(decompress(codes, scale))
        out_e.append(new_e)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)
