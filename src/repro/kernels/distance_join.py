"""Pallas TPU kernel: tiled pairwise MBR distance (Phase-3 hot loop).

The spatial join's inner loop tests every (driver, driven) MBR pair of a
block against the query distance. On TPU this is a VPU-bound elementwise
broadcast over an (M, N) tile grid; each (bm, bn) output tile lives in VMEM
with the two 4-wide box operands staged alongside.

Tiling: box components are split column-wise so tiles are (bm, 1) x (1, bn)
broadcasts — the output tile (bm, bn) f32 is the only VMEM-sized buffer
(default 256x256x4B = 256 KiB << 16 MiB VMEM), and the lane dimension (bn)
is a multiple of 128 to stay register-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, out_ref):
    # a_ref: (bm, 4) driver boxes; b_ref: (bn, 4) driven boxes
    a = a_ref[...]
    b = b_ref[...]
    ax0, ay0, ax1, ay1 = (a[:, 0:1], a[:, 1:2], a[:, 2:3], a[:, 3:4])
    bx0, by0, bx1, by1 = (b[:, 0], b[:, 1], b[:, 2], b[:, 3])
    dx = jnp.maximum(0.0, jnp.maximum(ax0 - bx1[None, :].reshape(1, -1),
                                      bx0[None, :].reshape(1, -1) - ax1))
    dy = jnp.maximum(0.0, jnp.maximum(ay0 - by1[None, :].reshape(1, -1),
                                      by0[None, :].reshape(1, -1) - ay1))
    out_ref[...] = jnp.sqrt(dx * dx + dy * dy)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def distance_join(driver: jnp.ndarray, driven: jnp.ndarray,
                  bm: int = 256, bn: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """Pairwise box min-distance matrix (M, N) float32.

    Inputs are padded up to tile multiples; padding rows produce garbage
    distances that the caller masks (ops.distance_join_mask handles it).
    """
    m, n = driver.shape[0], driven.shape[0]
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    drv = jnp.pad(driver.astype(jnp.float32), ((0, mp - m), (0, 0)))
    dvn = jnp.pad(driven.astype(jnp.float32), ((0, np_ - n), (0, 0)))
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(drv, dvn)
    return out[:m, :n]
