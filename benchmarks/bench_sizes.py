"""Tables 1/3: dataset characteristics + on-disk/in-memory index sizes."""
from __future__ import annotations

from . import common


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        store = ds.store
        tree = store.tree
        rows.append(common.row(
            f"table1_data/{ds_name}", 0.0,
            f"quads={store.n_quads};spatial={tree.n_objects};"
            f"nodes={tree.n_nodes}"))
        rows.append(common.row(
            f"table3_sizes/{ds_name}", 0.0,
            f"raw_mb={ds.raw_nbytes/2**20:.1f};"
            f"store_mb={store.nbytes()/2**20:.1f};"
            f"squadtree_mb={tree.nbytes()/2**20:.2f};"
            f"tree_frac={tree.nbytes()/max(ds.raw_nbytes,1)*100:.2f}%"))
    return rows
