"""Public jit'd wrappers for the Pallas kernels with CPU fallbacks.

On TPU the Pallas path compiles natively; on CPU we use interpret mode (for
tests) or the jnp reference (for the engine's `kernel` backend), keeping one
call site for both worlds.

Every query-path op here runs through `core/fault.run_op`: the dispatch is
an ordered failover chain (live route → interpret → oracle) so an exception,
watchdog timeout, or detected corruption in one backend degrades to the next
bit-identical one instead of failing the query. Per-(op, backend) circuit
breakers remember repeated failures; `BackendPolicy.resolve` consults them
so later plans skip a broken backend at plan time. The chains cost one
function call and a dict probe per *dispatch* (per driver block, not per
row); the structural validators only run when a `FaultPlan` is installed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fault as _fault
from . import block_scan as _bs
from . import bloom_probe as _bp
from . import distance_join as _dj
from . import flash_attention as _fa
from . import fused_topk_join as _ftj
from . import geom_refine as _gr
from . import merge_join as _mj
from . import morton_kernel as _mk
from . import ref
from . import tree_descend as _td


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _v_dist_matrix(out) -> bool:
    a = np.asarray(out)
    return bool(np.isfinite(a).all() and (a >= 0).all())


def distance_join_matrix(driver, driven, interpret: bool | None = None):
    driver = jnp.asarray(driver, dtype=jnp.float32)
    driven = jnp.asarray(driven, dtype=jnp.float32)

    def oracle():
        return ref.distance_join_ref(driver, driven)

    if _on_tpu() or interpret:
        live = "interpret" if (interpret and not _on_tpu()) else "kernel"
        attempts = [
            (live, lambda: _dj.distance_join(
                driver, driven, interpret=bool(interpret) and not _on_tpu())),
            ("oracle", oracle),
        ]
    else:
        # numpy-free CPU route: the jnp oracle is already the live backend;
        # the trailing attempt retries the same pure function (recovers
        # injected/transient failures, not deterministic ones)
        attempts = [("jit", oracle), ("oracle", oracle)]
    return _fault.run_op("distance_join_matrix", attempts,
                         validate=_v_dist_matrix)


def distance_join_mask(driver, driven, dist: float,
                       interpret: bool | None = None):
    return distance_join_matrix(driver, driven, interpret) <= dist


def fused_topk_join(driver, driven, driver_keys, driven_keys,
                    dist, theta, k: int = 64,
                    row_qid=None, col_qid=None,
                    interpret: bool | None = None):
    """Streaming per-row top-k distance join; see kernels/fused_topk_join.py.

    `dist` / `theta` may be scalars or per-driver-row (M,) arrays; `row_qid`
    / `col_qid` optional int32 query ids mask cross-query pairs so several
    queries' blocks share one launch (serve/spatial.py). Returns
    (scores (M, k), idx (M, k), counts (M,)) — the per-row partials the
    `fused` join backend consumes. On CPU without interpret mode this runs
    the dense jnp oracle (still per column *batch* when called through
    core/spatial_join.py, so peak memory stays independent of total N).
    """
    driver = jnp.asarray(driver, dtype=jnp.float32)
    driven = jnp.asarray(driven, dtype=jnp.float32)
    dk = jnp.asarray(driver_keys, dtype=jnp.float32)
    vk = jnp.asarray(driven_keys, dtype=jnp.float32)
    m, n = driver.shape[0], driven.shape[0]
    # one jit signature for scalar and per-row callers: always materialize
    # the per-row threshold columns and the qid planes
    dist_arr = jnp.broadcast_to(jnp.asarray(dist, dtype=jnp.float32), (m,))
    theta_arr = jnp.broadcast_to(jnp.asarray(theta, dtype=jnp.float32), (m,))
    rq = (jnp.zeros(m, jnp.int32) if row_qid is None
          else jnp.asarray(row_qid, dtype=jnp.int32))
    cq = (jnp.zeros(n, jnp.int32) if col_qid is None
          else jnp.asarray(col_qid, dtype=jnp.int32))
    def oracle():
        return _fused_ref_jit(driver, driven, dk, vk, dist_arr, theta_arr,
                              rq, cq, k)

    if _on_tpu() or interpret:
        live = "interpret" if (interpret and not _on_tpu()) else "kernel"
        attempts = [
            (live, lambda: _ftj.fused_topk_join(
                driver, driven, dk, vk, dist_arr, theta_arr, k=k,
                row_qid=rq, col_qid=cq,
                interpret=bool(interpret) and not _on_tpu())),
            ("oracle", oracle),
        ]
    else:
        attempts = [("jit", oracle), ("oracle", oracle)]
    return _fault.run_op("fused_topk_join", attempts,
                         validate=functools.partial(_v_fused, n=n))


def _v_fused(out, n: int) -> bool:
    # counts are *survivor* totals (they exceed k on overflow — that is the
    # recovery signal) so the structural bound is the column count
    scores, _, counts = out
    c = np.asarray(counts)
    return bool(not np.isnan(np.asarray(scores)).any()
                and (c >= 0).all() and (c <= n).all())


@functools.partial(jax.jit, static_argnames=("k",))
def _fused_ref_jit(driver, driven, dk, vk, dist, theta, rq, cq, k):
    return ref.fused_topk_join_ref(driver, driven, dk, vk, dist, theta, k,
                                   row_qid=rq, col_qid=cq)


def bucketed_min_core(a_planes, b_planes, interpret: bool | None = None):
    """Per-pair exact-geometry min squared distance over one padded
    size-class bucket; see kernels/geom_refine.py. a_planes / b_planes:
    dims-tuples of (B, m_pad) / (B, n_pad) float32 coordinate planes whose
    padding replicates real points (dims=2 raw x/y for euclid, dims=3
    unit-sphere X/Y/Z for haversine). Returns (B,) float32 core minima —
    the caller applies the metric's monotone distance transform in float64
    (core/spatial_join.py::core_to_dist)."""
    a_planes = tuple(jnp.asarray(p, dtype=jnp.float32) for p in a_planes)
    b_planes = tuple(jnp.asarray(p, dtype=jnp.float32) for p in b_planes)

    def host():
        # CPU: the loop-structured host twin (kernel numerics, no (B, m, n)
        # cube); ref.bucketed_min_core_ref stays the test oracle
        return _gr.bucketed_min_core_host(a_planes, b_planes)

    if _on_tpu() or interpret:
        live = "interpret" if (interpret and not _on_tpu()) else "kernel"
        attempts = [
            (live, lambda: _gr.bucketed_min_core(
                a_planes, b_planes,
                interpret=bool(interpret) and not _on_tpu())),
            ("oracle", host),
        ]
    else:
        attempts = [("jit", host), ("oracle", host)]
    return _fault.run_op("bucketed_min_core", attempts, validate=_v_min_core)


def _v_min_core(out) -> bool:
    a = np.asarray(out)
    return bool(np.isfinite(a).all() and (a >= 0).all())


# Rank-pass backend dispatch for the relational merge join (core/join.py).
# "numpy" is the oracle (np.searchsorted, fastest on CPU); "cpu" is the
# jitted loop-structured twin; "kernel" routes through the Pallas kernel on
# TPU and the dense jnp oracle on CPU; "interpret" forces the Pallas kernel
# in interpret mode (tests). "auto" resolves once per process.
RANK_BACKENDS = ("auto", "numpy", "cpu", "kernel", "interpret")
_auto_rank_backend: str | None = None


def resolve_rank_backend(backend: str | None) -> str:
    global _auto_rank_backend
    b = backend or "auto"
    if b not in RANK_BACKENDS:
        raise ValueError(f"unknown merge-join rank backend {b!r}")
    if b != "auto":
        return b
    if _auto_rank_backend is None:
        _auto_rank_backend = "kernel" if _on_tpu() else "numpy"
    return _auto_rank_backend


def split_key_planes(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """int64 keys -> (hi, lo) int32 planes whose (signed hi, signed lo)
    lexicographic order equals the int64 order (the lo sign bit is flipped
    so signed int32 compares act as unsigned compares on the low half)."""
    x = np.asarray(x, dtype=np.int64)
    hi = (x >> np.int64(32)).astype(np.int32)
    lo = (x & np.int64(0xFFFFFFFF)).astype(np.uint32)
    return hi, (lo ^ np.uint32(1 << 31)).view(np.int32)


def merge_join_ranks(table, probes, backend: str | None = None,
                     interpret: bool | None = None, side: str = "both"):
    """Insertion ranks of `probes` in the sorted int64 `table`: with
    side="both" (the join's CSR widths) returns (lo, hi) int64 where
    lo = searchsorted-left and hi = searchsorted-right; side="left"/"right"
    returns just that bound (the semijoin membership / SIP interval tests —
    the numpy backend then runs a single searchsorted and the CPU twin a
    single binary search; the counting kernel's pass yields both for free).
    The rank pass of the relational merge
    join; see kernels/merge_join.py. Keys must be < int64-max (the kernel's
    padding sentinel)."""
    if side not in ("both", "left", "right"):
        raise ValueError(f"unknown rank side {side!r}")
    backend = resolve_rank_backend(
        "interpret" if (interpret and backend in (None, "auto")) else backend)
    table = np.asarray(table, dtype=np.int64)
    probes = np.asarray(probes, dtype=np.int64)
    m = len(probes)
    if len(table) == 0 or m == 0:
        z = np.zeros(m, dtype=np.int64)
        return (z, z.copy()) if side == "both" else z

    def numpy_ranks():
        if side != "both":
            return np.searchsorted(table, probes, side)
        return (np.searchsorted(table, probes, "left"),
                np.searchsorted(table, probes, "right"))

    if backend == "numpy":
        attempts = [("numpy", numpy_ranks), ("oracle", numpy_ranks)]
    else:
        def accel(backend=backend):
            # pow2 size classes bound jit recompiles; the int64-max sentinel
            # compares greater than every probe, so table padding never
            # changes a rank, and padded probe rows are sliced off below
            t_hi, t_lo = split_key_planes(_pad_pow2(table, (1 << 63) - 1))
            p_hi, p_lo = split_key_planes(_pad_pow2(probes, 0))
            if backend == "cpu":
                out = _mj.merge_join_ranks_host(t_hi, t_lo, p_hi, p_lo,
                                                side=side)
                if side != "both":
                    return np.asarray(out[:m]).astype(np.int64)
                lo, hi = out
            elif backend == "kernel" and not _on_tpu():
                lo, hi = _ranks_ref_jit(jnp.asarray(t_hi), jnp.asarray(t_lo),
                                        jnp.asarray(p_hi), jnp.asarray(p_lo))
            else:
                lo, hi = _mj.merge_join_ranks(
                    jnp.asarray(t_hi), jnp.asarray(t_lo),
                    jnp.asarray(p_hi), jnp.asarray(p_lo),
                    interpret=backend == "interpret" and not _on_tpu())
            lo = np.asarray(lo[:m]).astype(np.int64)
            hi = np.asarray(hi[:m]).astype(np.int64)
            return ((lo, hi) if side == "both"
                    else (lo if side == "left" else hi))

        attempts = [(backend, accel), ("oracle", numpy_ranks)]
    return _fault.run_op(
        "merge_join_ranks", attempts,
        validate=functools.partial(_v_ranks, n=len(table), side=side))


def _v_ranks(out, n: int, side: str) -> bool:
    lo, hi = out if side == "both" else (out, out)
    lo, hi = np.asarray(lo), np.asarray(hi)
    return bool((lo >= 0).all() and (hi <= n).all() and (lo <= hi).all())


def _pad_pow2(x: np.ndarray, fill: int) -> np.ndarray:
    p = 1 << max(int(len(x) - 1).bit_length(), 3)
    if p == len(x):
        return x
    return np.concatenate([x, np.full(p - len(x), fill, dtype=np.int64)])


@jax.jit
def _ranks_ref_jit(t_hi, t_lo, p_hi, p_lo):
    return ref.merge_join_ranks_ref(t_hi, t_lo, p_hi, p_lo)


def f64_sort_keys(x: np.ndarray) -> np.ndarray:
    """IEEE-754 doubles -> order-isomorphic int64 sort keys (host, exact).

    The classic total-order flip: positives keep their bit pattern with the
    sign bit toggled, negatives are complemented; -0.0 is canonicalized to
    +0.0 first so the two zero encodings stay equal. int64 comparisons on
    the keys then agree bit-for-bit with f64 ``<=`` on the inputs, which
    lets the 32-bit kernels run the engine's f64 box tests exactly. Finite
    inputs map strictly inside (int64-min, int64-max), so both extremes
    remain free for never-matching padding sentinels.
    """
    x = np.where(x == 0.0, 0.0, np.asarray(x, dtype=np.float64))
    u = np.asarray(x, dtype=np.float64).view(np.uint64)
    sign = np.uint64(1) << np.uint64(63)
    key_u = np.where(u & sign != 0, ~u, u | sign)
    return (key_u ^ sign).view(np.int64)


# never-intersecting padding box in f64_sort_keys space: mins above every
# real max key, maxs below every real min key (rows are x0, y0, x2, y3)
DESCEND_PAD_BOX = np.array(
    [(1 << 63) - 1, (1 << 63) - 1, -(1 << 63), -(1 << 63)], dtype=np.int64)


def tree_descend(node_keys, cs_path, box_keys, backend: str = "kernel",
                 interpret: bool | None = None):
    """Fused Phase-1 candidate-node pass; see kernels/tree_descend.py.

    node_keys (4, N) int64 `f64_sort_keys` planes of the node MBRs (rows
    x0, y0, x2, y3); cs_path (N,) bool root-path Bloom verdicts; box_keys
    (B, M, 4) int64 keys of the expanded driver boxes with padding rows
    pre-set to `DESCEND_PAD_BOX`. Returns the (B, N) bool candidate masks.
    backend: "kernel" (Pallas on TPU, jitted dense oracle on CPU) or
    "interpret" (Pallas interpret mode, tests). The host frontier is the
    "numpy" backend and never reaches this dispatch (core/squadtree.py).
    """
    if backend not in ("kernel", "interpret"):
        raise ValueError(f"unknown tree-descend backend {backend!r}")
    node_keys = np.asarray(node_keys, dtype=np.int64)
    box_keys = np.asarray(box_keys, dtype=np.int64)
    n = node_keys.shape[1]
    b, m = box_keys.shape[0], box_keys.shape[1]
    if n == 0 or b == 0:
        return np.zeros((b, n), dtype=bool)
    # pow2 size classes bound jit recompiles: padded blocks/boxes carry the
    # never-intersecting sentinel box and are sliced off / ignored below
    bp = 1 << max(int(b - 1).bit_length(), 0)
    mp = 1 << max(int(m - 1).bit_length(), 3)
    if bp != b or mp != m:
        padded = np.empty((bp, mp, 4), dtype=np.int64)
        padded[:] = DESCEND_PAD_BOX
        padded[:b, :m] = box_keys
        box_keys = padded
    n_hi, n_lo = split_key_planes(node_keys)
    b_hi, b_lo = split_key_planes(box_keys)
    cs = np.asarray(cs_path).astype(np.int32)

    def oracle():
        return _descend_ref_jit(jnp.asarray(n_hi), jnp.asarray(n_lo),
                                jnp.asarray(cs), jnp.asarray(b_hi),
                                jnp.asarray(b_lo))

    if backend == "kernel" and not _on_tpu():
        attempts = [("kernel", oracle), ("oracle", oracle)]
    else:
        attempts = [
            (backend, lambda: _td.tree_descend(
                jnp.asarray(n_hi), jnp.asarray(n_lo), jnp.asarray(cs),
                jnp.asarray(b_hi), jnp.asarray(b_lo),
                interpret=backend == "interpret" and not _on_tpu())),
            ("oracle", oracle),
        ]
    out = _fault.run_op("tree_descend", attempts, validate=_v_mask01)
    return np.asarray(out[:b]) != 0


def tree_descend_sharded(node_keys, cs_path, box_keys,
                         backend: str = "kernel"):
    """Phase-1 descent over every store shard in one dispatch.

    node_keys (S, 4, N_max) stacked per-shard `f64_sort_keys` planes (pad
    columns carry `DESCEND_PAD_BOX`); cs_path (S, N_max) bool with padded
    nodes False; box_keys (B, M, 4) shared driver boxes. Returns (S, B,
    N_max) bool masks.

    The live route lays the shard axis over a `launch/mesh.make_shard_mesh`
    mesh via shard_map — each device sweeps its resident shards with the
    SAME per-shard descent `tree_descend` launches (Pallas kernel on TPU,
    the jitted dense oracle on CPU), so device count scales shards without
    touching the kernel. Failover: a sequential host loop of per-shard
    `tree_descend` calls (each with its own internal chain). Both routes
    are exact integer-compare passes — bit-identical.
    """
    if backend not in ("kernel", "interpret"):
        raise ValueError(f"unknown tree-descend backend {backend!r}")
    node_keys = np.asarray(node_keys, dtype=np.int64)
    box_keys = np.asarray(box_keys, dtype=np.int64)
    s, _, n = node_keys.shape
    b, m = box_keys.shape[0], box_keys.shape[1]
    if s == 0 or n == 0 or b == 0:
        return np.zeros((s, b, n), dtype=bool)
    bp = 1 << max(int(b - 1).bit_length(), 0)
    mp = 1 << max(int(m - 1).bit_length(), 3)
    padded = box_keys
    if bp != b or mp != m:
        padded = np.empty((bp, mp, 4), dtype=np.int64)
        padded[:] = DESCEND_PAD_BOX
        padded[:b, :m] = box_keys
    cs = np.asarray(cs_path).astype(np.int32)

    def via_shard_map():
        from ..launch import mesh as _mesh
        msh = _mesh.make_shard_mesh(s)
        spec = jax.sharding.PartitionSpec
        n_hi, n_lo = split_key_planes(node_keys)
        b_hi, b_lo = split_key_planes(padded)
        pallas = backend == "interpret" or _on_tpu()

        def body(nh, nl, c, bh, bl):
            def one(args):
                nh1, nl1, c1 = args
                if pallas:
                    return _td.tree_descend(
                        nh1, nl1, c1, bh, bl,
                        interpret=backend == "interpret" and not _on_tpu())
                return ref.tree_descend_ref(nh1, nl1, c1, bh, bl)
            return jax.lax.map(one, (nh, nl, c))

        f = _mesh.shard_map_compat(
            body, msh,
            in_specs=(spec("shard"), spec("shard"), spec("shard"),
                      spec(), spec()),
            out_specs=spec("shard"))
        out = f(jnp.asarray(n_hi), jnp.asarray(n_lo), jnp.asarray(cs),
                jnp.asarray(b_hi), jnp.asarray(b_lo))
        return np.asarray(out)[:, :b]

    def sequential():
        return np.stack([
            tree_descend(node_keys[i], cs[i], box_keys, backend=backend)
            .astype(np.int32) for i in range(s)])

    attempts = [("shard_map", via_shard_map), ("sequential", sequential)]
    out = _fault.run_op("tree_descend_sharded", attempts, validate=_v_mask01)
    return np.asarray(out) != 0


def _v_mask01(out) -> bool:
    a = np.asarray(out)
    return bool(a.size == 0 or (a.min() >= 0 and a.max() <= 1))


@jax.jit
def _descend_ref_jit(n_hi, n_lo, cs, b_hi, b_lo):
    return ref.tree_descend_ref(n_hi, n_lo, cs, b_hi, b_lo)


def bloom_probe(bits, keys, k: int = 3, interpret: bool | None = None):
    """bits (B, W) uint32 pre-gathered filter rows; keys (B,) int64."""
    keys = np.asarray(keys, dtype=np.int64).view(np.uint64)
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
                     .view(np.int32))
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32).view(np.int32))
    bits = jnp.asarray(bits)

    def oracle():
        # int verdict plane (not bool) so corrupt-injection has an
        # out-of-domain value for the validator to catch
        return jnp.asarray(ref.bloom_probe_ref(bits, lo, hi, k), jnp.int32)

    if _on_tpu() or interpret:
        live = "interpret" if (interpret and not _on_tpu()) else "kernel"
        attempts = [
            (live, lambda: _bp.bloom_probe(
                bits, lo, hi, k=k,
                interpret=bool(interpret) and not _on_tpu())),
            ("oracle", oracle),
        ]
    else:
        attempts = [("jit", oracle), ("oracle", oracle)]
    out = _fault.run_op("bloom_probe", attempts, validate=_v_mask01)
    return np.asarray(out) == 1


def block_scan(scores, theta: float, interpret: bool | None = None):
    scores = jnp.asarray(scores, dtype=jnp.float32)
    if _on_tpu() or interpret:
        return _bs.block_scan(scores, theta,
                              interpret=bool(interpret) and not _on_tpu())
    return ref.block_scan_ref(scores, theta)


def morton_encode(cx, cy, interpret: bool | None = None):
    cx = jnp.asarray(cx, dtype=jnp.int32)
    cy = jnp.asarray(cy, dtype=jnp.int32)
    if _on_tpu() or interpret:
        return _mk.morton_encode(cx, cy,
                                 interpret=bool(interpret) and not _on_tpu())
    return ref.morton_ref(cx, cy)


def flash_attention(q, k, v, causal: bool = True,
                    interpret: bool | None = None):
    if _on_tpu() or interpret:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   interpret=bool(interpret) and not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)
