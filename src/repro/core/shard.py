"""Morton-prefix sharding of the S-QuadTree store.

The object SoA of an `SQuadTree` is sorted by (S, Z, I, L) id, and the id
codec makes any subtree one contiguous id interval — so *any* contiguous
split of the sorted object array is a set of Morton-prefix ranges, and each
range rebuilds into a self-contained per-shard `SQuadTree` that keeps the
GLOBAL ids (`build(oids=...)`). Phases 1–2 then run per shard: candidate
search and node selection against the shard's own (smaller) tree, SIP
filter material clipped to the shard's id range so the per-shard driven
retrievals partition the result set exactly — the union over shards is
bit-identical to the single-host engine, and the global θ read between
shard passes prunes later shards for free (the θ bound is exact).

The fused descent stacks every shard's node planes into one
`kernels/ops.tree_descend_sharded` dispatch laid over a
`launch/mesh.make_shard_mesh` shard_map, so device count scales shard
count without touching the per-shard kernel.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import node_select, squadtree
from .squadtree import SQuadTree, build as build_tree
from .store import (QuadStore, _entity_cs_csr, _sorted_lut,
                    lut_get)


@dataclasses.dataclass
class TreeShard:
    """One shard's tree plus the closed global-id range it owns.

    `filter_material` clips the I-Range intervals to [id_lo, id_hi]: a
    shard tree's upper nodes (root included) span the whole id space, so
    without the clip two shards would both emit the driven rows of ids
    they don't own and the union would double-count. E-list ids need no
    clip — shard elists are built from shard-owned objects only.

    ``clip=False`` marks the degenerate single-view over an unsharded
    store: filter material passes through untouched, so the unsharded
    engine path is literally the old code path.
    """
    tree: SQuadTree
    id_lo: int = 0
    id_hi: int = 0
    clip: bool = True

    @property
    def n_objects(self) -> int:
        return self.tree.n_objects

    def filter_material(self, v_star: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
        intervals, explicit = self.tree.filter_material(v_star)
        if self.clip and len(intervals):
            lo = np.maximum(intervals[:, 0], self.id_lo)
            hi = np.minimum(intervals[:, 1], self.id_hi)
            keep = lo <= hi
            intervals = np.stack([lo[keep], hi[keep]], axis=1)
        return intervals, explicit


def shard_views(store: QuadStore) -> list[TreeShard]:
    """The store's shard list; a single no-clip view for unsharded stores."""
    shards = getattr(store, "tree_shards", None)
    if shards:
        return list(shards)
    return [TreeShard(store.tree, clip=False)]


def whole_view(store: QuadStore) -> list[TreeShard]:
    """Single global-tree view (the SIP-disabled path: with no interval
    filtering, per-shard retrieval would replicate the driven side)."""
    return [TreeShard(store.tree, clip=False)]


@dataclasses.dataclass
class ShardedQuadStore(QuadStore):
    """A QuadStore whose SQuadTree is partitioned by Morton-prefix range.

    The global `tree` is retained for id-keyed lookups that are not part
    of the per-shard Phase 1–2 sweep (`spatial_box_of`, `geom_rows`, the
    geometry pool rows); `tree_shards` carries the per-shard trees the
    executor iterates.
    """
    tree_shards: list = dataclasses.field(default_factory=list)

    @property
    def n_shards(self) -> int:
        return len(self.tree_shards)

    def shard_tree_nbytes(self) -> int:
        return sum(sh.tree.nbytes() for sh in self.tree_shards)


def shard_store(store: QuadStore, n_shards: int,
                leaf_capacity: int = 64,
                compressed: bool = True) -> ShardedQuadStore:
    """Partition `store` into `n_shards` contiguous Morton-prefix ranges.

    Each shard rebuilds a plain `SQuadTree` over its object slice with the
    precomputed GLOBAL ids and the global extent/l_max/Bloom geometry, so
    id-interval semantics (and the one shared `PreparedKeys`) carry over
    unchanged. Per-entity in/out characteristic sets are recomputed from
    the remapped quads — the remap is bijective, so the sets equal the
    build-time ones. ``compressed`` packs each shard's E-list tier
    (`SQuadTree.pack_elists`).

    Shards are equal-object-count splits; empty ranges (more shards than
    objects) are dropped.
    """
    tree = store.tree
    if tree is None:
        raise ValueError("cannot shard a store with no spatial index")
    m = tree.n_objects
    n_shards = max(1, int(n_shards))
    bounds = [round(i * m / n_shards) for i in range(n_shards + 1)]
    cs_keys, cs_vals = _sorted_lut(store.cs_of_entity)
    bank = tree.bloom_self
    shards: list[TreeShard] = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        if b <= a:
            continue
        oids = tree.obj_ids[a:b]
        cs_self = lut_get(cs_keys, cs_vals, oids)
        cs_in, cs_out = _entity_cs_csr(store.quads, oids, cs_keys, cs_vals)
        sub = build_tree(
            tree.obj_entity[a:b], tree.obj_mbr[a:b], cs_self,
            cs_in=cs_in, cs_out=cs_out,
            extent=tree.extent, l_max=tree.l_max,
            leaf_capacity=leaf_capacity,
            bloom_words=bank.nbits // 32, bloom_k=bank.k,
            oids=oids, boxes_normalized=True, compressed=compressed)
        shards.append(TreeShard(sub, id_lo=int(oids[0]), id_hi=int(oids[-1])))
    fields = {f.name: getattr(store, f.name)
              for f in dataclasses.fields(QuadStore)}
    return ShardedQuadStore(**fields, tree_shards=shards)


# ---------------------------------------------------------------------------
# Sharded Phases 1–2
# ---------------------------------------------------------------------------

def candidate_nodes_sharded(shards: list[TreeShard], box_sets, dist_norm,
                            driven_cs: np.ndarray,
                            prepared=None, probe_backend=None,
                            descend_backend=None,
                            cs_paths: list | None = None) -> list[np.ndarray]:
    """Per-shard Phase-1 candidate masks for one shared CS set.

    Returns a list aligned with `shards` of (B, N_s) bool masks. The host
    frontier route loops shards (each already batched over blocks); the
    fused routes stack every shard's node planes into ONE
    `ops.tree_descend_sharded` dispatch (shard_map over the shard mesh,
    sequential per-shard failover) — both bit-identical to calling each
    shard's `candidate_nodes` alone.
    """
    driven_cs = np.asarray(driven_cs, dtype=np.int64)
    dback = squadtree.resolve_descend_backend(descend_backend)
    if cs_paths is None:
        cs_paths = [None] * len(shards)
    if dback == "numpy" or len(shards) == 1:
        return [sh.tree.candidate_nodes(
                    box_sets, dist_norm, driven_cs, prepared=prepared,
                    probe_backend=probe_backend, descend_backend=dback,
                    cs_path=cs_paths[si])
                for si, sh in enumerate(shards)]
    from ..kernels import ops
    from . import geometry
    boxes = squadtree._pad_box_sets(box_sets)
    n_b = len(boxes)
    sizes = [sh.tree.n_nodes for sh in shards]
    if not (n_b and len(driven_cs) and boxes.shape[1]):
        return [np.zeros((n_b, n), dtype=bool) for n in sizes]
    paths = [cs_paths[si] if cs_paths[si] is not None
             else sh.tree.cs_path_mask(driven_cs, prepared=prepared,
                                       probe_backend=probe_backend)
             for si, sh in enumerate(shards)]
    n_max = max(sizes)
    stacked = np.empty((len(shards), 4, n_max), dtype=np.int64)
    stacked[:] = ops.DESCEND_PAD_BOX[None, :, None]
    cs_stack = np.zeros((len(shards), n_max), dtype=bool)
    for si, sh in enumerate(shards):
        stacked[si, :, :sizes[si]] = sh.tree._node_key_planes()
        cs_stack[si, :sizes[si]] = paths[si]
    d = (dist_norm if np.ndim(dist_norm) == 0
         else np.asarray(dist_norm, dtype=np.float64)[:, None])
    expanded = geometry.expand_boxes(boxes, d)
    keys = ops.f64_sort_keys(expanded)
    pad = ~np.isfinite(boxes[..., 0])
    if pad.any():
        keys[pad] = ops.DESCEND_PAD_BOX
    masks = ops.tree_descend_sharded(stacked, cs_stack, keys, backend=dback)
    return [masks[si, :, :sizes[si]] for si in range(len(shards))]


def sip_select(shards: list[TreeShard], box_sets, dist_norm,
               driven_cs: np.ndarray, prepared, probe_backend,
               descend_backend, cs_paths, params, card_all: list
               ) -> list[list[np.ndarray]]:
    """Phases 1+2 across shards: candidate masks then the per-shard V*
    selection DP. Returns per-BLOCK lists of per-shard V* arrays (the
    shape `QueryCursor._vstars` stores)."""
    masks = candidate_nodes_sharded(
        shards, box_sets, dist_norm, driven_cs, prepared=prepared,
        probe_backend=probe_backend, descend_backend=descend_backend,
        cs_paths=cs_paths)
    per_shard = [node_select.select_batch(sh.tree, masks[si], driven_cs,
                                          params, card_all[si])
                 for si, sh in enumerate(shards)]
    n_blocks = len(masks[0]) if len(shards) else 0
    return [[per_shard[si][b] for si in range(len(shards))]
            for b in range(n_blocks)]
