"""Geographica-shaped query diversity: range / within-distance / kNN /
spatial-join selections (no top-k ranking), each at two dataset scales and
across 1/2/4 shards.

Geographica's micro benchmark stresses exactly these shapes; here they run
through the same SIP + fused-kernel pipeline as the paper's top-k queries
(core/shapes.py), so this suite tracks how much of the top-k machinery's
pruning transfers to plain spatial selections. ``derived`` carries the
result cardinality — a free cross-check that a perf change did not silently
change semantics.
"""
from __future__ import annotations

from repro import StreakEngine
from repro.core.query import Query, SpatialFilter, TriplePattern, Var
from repro.core.shard import shard_store
from repro.data import synth_rdf

from . import common

_GEO_CACHE: dict = {}

# (scale label, n_per_class) — "small" is Geographica-micro-sized, "large"
# is the regime where block scanning dominates per-query overheads
SCALES = (("small", 800), ("large", 6000))
SHARDS = (1, 2, 4)


def _dataset(n_per_class: int):
    if n_per_class not in _GEO_CACHE:
        _GEO_CACHE[n_per_class] = synth_rdf.make_lgd(
            n_per_class=n_per_class, seed=3, block=1024)
    return _GEO_CACHE[n_per_class]


def _patterns(ns, cls, suffix=""):
    p, g = Var(f"place{suffix}"), Var(f"g{suffix}")
    return p, g, (
        TriplePattern(p, Var(f"tp{suffix}"), ns[cls], g=Var(f"r{suffix}")),
        TriplePattern(Var(f"r{suffix}"), ns["hasConfidence"],
                      Var(f"conf{suffix}")),
        TriplePattern(p, ns["hasGeometry"], g),
    )


def _queries(ns) -> list:
    pa, ga, pats_a = _patterns(ns, "class:hotel")
    pb, gb, pats_b = _patterns(ns, "class:park", "2")
    return [
        ("range", Query(select=(pa,), patterns=pats_a, ranking=None,
                        spatial=SpatialFilter(ga, None,
                                              window=(20.0, 15.0,
                                                      55.0, 45.0)))),
        ("within", Query(select=(pa,), patterns=pats_a, ranking=None,
                         spatial=SpatialFilter(ga, None, dist=12.0,
                                               center=(50.0, 50.0)))),
        ("knn", Query(select=(pa, pb), patterns=pats_a + pats_b,
                      ranking=None,
                      spatial=SpatialFilter(ga, gb, knn=3))),
        ("join", Query(select=(pa, pb), patterns=pats_a + pats_b,
                       ranking=None,
                       spatial=SpatialFilter(ga, gb, dist=2.0))),
    ]


def run() -> list:
    rows = []
    for scale, n_per_class in SCALES:
        ds = _dataset(n_per_class)
        for n_shards in SHARDS:
            store = (ds.store if n_shards == 1
                     else shard_store(ds.store, n_shards))
            eng = StreakEngine(store)
            for shape, q in _queries(ds.ns):
                scores, _, _ = eng.execute(q)  # warm scan cache + card check
                t = common.timeit(lambda: eng.execute(q))
                rows.append(common.row(
                    f"geographica/{scale}/{shape}/shards{n_shards}", t,
                    f"rows={len(scores)}"))
    return rows


if __name__ == "__main__":  # pragma: no cover - manual convenience
    for r in run():
        print(r)
