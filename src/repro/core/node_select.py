"""Optimal filter-node selection: Algorithm 1 / Theorem 3.1.

Given the candidate node set V (Phase 1) the DP picks V* ⊆ V that covers every
join-relevant object while minimizing

    cost(a)  = alpha_io * |CS(a)|  +  alpha_cpu * |E-list(a)|
    xi(a)    = alpha_merge * |E-list(a)|            (merge cost contribution)

with the hierarchical merge term mu(a) = sum_{j in gamma(a)} xi*(j) charged
whenever more than one selected branch contributes an E-list. Nodes are laid
out parents-before-children during the build, so one reverse sweep is the
bottom-up order — O(N), matching the theorem's linearity claim.

Decisions are stored per node (EMPTY / SELF / CHILDREN) and V* is
reconstructed by a root walk, keeping the DP allocation-free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .squadtree import SQuadTree

EMPTY, SELF, CHILDREN = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class SelectParams:
    alpha_io: float = 1.0
    alpha_cpu: float = 0.05
    alpha_merge: float = 0.01


def _elist_all(tree: SQuadTree) -> np.ndarray:
    """All-node E-list sizes, memoized on the tree (query-invariant — the
    serving layer's pooled select runs every engine step, and re-walking the
    CSR for a static vector was its dominant per-step setup cost)."""
    el = getattr(tree, "_elist_all_cache", None)
    if el is None:
        el = tree.elist_size(np.arange(tree.n_nodes)).astype(np.float64)
        el.setflags(write=False)
        tree._elist_all_cache = el
    return el


def node_costs_base(tree: SQuadTree, driven_cs: np.ndarray,
                    params: SelectParams,
                    card_all: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Block-invariant (base_cost, xi) per node; cost(a) = base where a ∈ V.

    Multi-query form: `driven_cs` may be a list of per-block CS arrays (or
    `card_all` a precomputed ``(B, N)`` stack) — `base` then carries one
    cost row per block; `xi` stays CS-independent.
    """
    if card_all is None:
        if isinstance(driven_cs, (list, tuple)):
            card_all = np.stack([tree.cs_stats.cardinality_all(c)
                                 for c in driven_cs])
        else:
            card_all = tree.cs_stats.cardinality_all(driven_cs)
    el = _elist_all(tree)
    base = params.alpha_io * card_all + params.alpha_cpu * el
    xi = params.alpha_merge * el
    return base, xi


def node_costs(tree: SQuadTree, in_v: np.ndarray, driven_cs: np.ndarray,
               params: SelectParams,
               card_all: np.ndarray | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
    """(cost, xi) per node. |CS(a)| = driven-CS cardinality stored at a.

    Pass `card_all` (tree.cs_stats.cardinality_all(driven_cs)) to amortize
    the CSR pass across driver blocks — it is query-, not block-, dependent.
    """
    base, xi = node_costs_base(tree, driven_cs, params, card_all)
    return np.where(in_v, base, 0.0), xi


def select_batch(tree: SQuadTree, in_v: np.ndarray, driven_cs: np.ndarray,
                 params: SelectParams = SelectParams(),
                 card_all: np.ndarray | None = None) -> list[np.ndarray]:
    """V* for a batch of candidate masks at once.

    `in_v` is ``(B, n_nodes)`` — one Phase-1 mask per driver block. The DP
    recurrences are identical to the looped `select_looped` but run over all
    B blocks per level (the per-node cost/xi material is block-invariant, so
    it is computed once), the per-level node sets come from the tree's level
    buckets instead of an O(N) rescan, and V* is reconstructed by a
    vectorized top-down per-level sweep instead of a python stack walk.
    Returns a list of B sorted node-index arrays, bit-identical to the
    looped oracle applied per block.

    Multi-query form: pass `driven_cs` as a list of per-block CS arrays, or
    `card_all` as a precomputed ``(B, N)`` stack — each block's DP then runs
    under its own query's cost row (the serving layer's cross-query batch).
    """
    in_v = np.atleast_2d(np.asarray(in_v, dtype=bool))
    n_b, n = in_v.shape
    assert n == tree.n_nodes
    base, xi = node_costs_base(tree, driven_cs, params, card_all)

    children = tree.node_children
    # The DP state of node `a` can only be non-trivial when subtree(a)
    # intersects some block's V (nonempty needs in_v at `a` or a live
    # descendant), so the whole sweep runs over a *compact* ancestor
    # closure of the union candidate set — everything outside keeps its
    # zero/EMPTY state implicitly, exactly as in the looped oracle.
    relevant = in_v.any(axis=0)                     # (N,)
    parent = tree.node_parent
    for lvl in range(tree.n_levels - 1, 0, -1):
        nodes = tree.level_nodes(lvl)
        rel = nodes[relevant[nodes]]
        if len(rel):
            relevant[parent[rel]] = True
    ridx = np.flatnonzero(relevant)                 # sorted node ids
    n_r = len(ridx)
    if n_r == 0:
        return [np.empty(0, dtype=np.int64) for _ in range(n_b)]
    rank = np.full(n, -1, dtype=np.int64)
    rank[ridx] = np.arange(n_r)

    in_v_r = in_v[:, ridx]                          # (B, R)
    base_r = base[:, ridx] if base.ndim == 2 else base[ridx][None]
    cost = np.where(in_v_r, base_r, 0.0)
    xi_r = xi[ridx]
    sigma = np.zeros((n_b, n_r))                    # sigma*(a)
    xistar = np.zeros((n_b, n_r))                   # xi*(a)
    nonempty = np.zeros((n_b, n_r), dtype=bool)
    decision = np.full((n_b, n_r), EMPTY, dtype=np.int8)

    # per-level compact node sets + remapped children, reused top-down
    lvl_local, lvl_kid_rank, lvl_kid_valid = [], [], []
    for lvl in range(tree.n_levels):
        nodes = tree.level_nodes(lvl)
        nodes = nodes[relevant[nodes]]
        kids = children[nodes]                      # (m, 4)
        kid_rank = rank[np.where(kids >= 0, kids, 0)]
        # a child outside the closure can never be nonempty: drop it
        valid = (kids >= 0) & (kid_rank >= 0)
        lvl_local.append(rank[nodes])
        lvl_kid_rank.append(np.where(valid, kid_rank, 0))
        lvl_kid_valid.append(valid)

    # bottom-up: one vectorized sweep per level bucket, deepest first (the
    # recurrences only reference children, which live one level down)
    for lvl in range(tree.n_levels - 1, -1, -1):
        local = lvl_local[lvl]
        if len(local) == 0:
            continue
        valid, kid_idx = lvl_kid_valid[lvl], lvl_kid_rank[lvl]
        live = valid[None] & nonempty[:, kid_idx]   # (B, m, 4)
        n_live = live.sum(axis=2)
        xi_children = np.where(live, xistar[:, kid_idx], 0.0).sum(axis=2)
        mu = np.where(n_live > 1, xi_children, 0.0)
        sig_children = np.where(live, sigma[:, kid_idx], 0.0).sum(axis=2) + mu
        v = in_v_r[:, local]
        # SELF when: in V and (no live children or cost <= children cost)
        take_self = v & ((n_live == 0) | (cost[:, local] <= sig_children))
        take_kids = (~take_self) & (n_live > 0)
        decision[:, local] = np.where(take_self, SELF,
                                      np.where(take_kids, CHILDREN, EMPTY))
        sigma[:, local] = np.where(take_self, cost[:, local],
                                   np.where(take_kids, sig_children, 0.0))
        xistar[:, local] = np.where(take_self, xi_r[None, local],
                                    np.where(take_kids, xi_children, 0.0))
        nonempty[:, local] = take_self | take_kids

    # top-down reconstruction: propagate reachability level by level
    selected = np.zeros((n_b, n_r), dtype=bool)
    reach = np.zeros((n_b, n_r), dtype=bool)
    if rank[0] >= 0:
        reach[:, rank[0]] = True
    for lvl in range(tree.n_levels):
        local = lvl_local[lvl]
        if len(local) == 0:
            continue
        r = reach[:, local]
        dec = decision[:, local]
        selected[:, local] = r & (dec == SELF)
        expand = r & (dec == CHILDREN)              # (B, m)
        if not expand.any():
            continue
        valid, kid_idx = lvl_kid_valid[lvl], lvl_kid_rank[lvl]
        vi, qi = np.nonzero(valid)
        kn = kid_idx[vi, qi]
        reach[:, kn] = expand[:, vi] & nonempty[:, kn]
    return [ridx[np.flatnonzero(selected[b])] for b in range(n_b)]


def select(tree: SQuadTree, in_v: np.ndarray, driven_cs: np.ndarray,
           params: SelectParams = SelectParams(),
           card_all: np.ndarray | None = None) -> np.ndarray:
    """Compute V* (node indices). Empty when V is empty.

    Single-block entry point over `select_batch` (B = 1)."""
    in_v = np.asarray(in_v, dtype=bool)
    if not in_v.any():
        return np.empty(0, dtype=np.int64)
    return select_batch(tree, in_v[None], driven_cs, params, card_all)[0]


def select_looped(tree: SQuadTree, in_v: np.ndarray, driven_cs: np.ndarray,
                  params: SelectParams = SelectParams(),
                  card_all: np.ndarray | None = None) -> np.ndarray:
    """Per-block oracle for `select_batch`: O(N·L) level rescans and a
    python-stack reconstruction (kept for cross-checking bit-identicality)."""
    n = tree.n_nodes
    in_v = np.asarray(in_v, dtype=bool)
    if not in_v.any():
        return np.empty(0, dtype=np.int64)
    cost, xi = node_costs(tree, in_v, driven_cs, params, card_all)

    sigma = np.zeros(n)          # sigma*(a)
    xistar = np.zeros(n)         # xi*(a)
    nonempty = np.zeros(n, dtype=bool)
    decision = np.full(n, EMPTY, dtype=np.int8)

    children = tree.node_children
    levels = tree.node_level
    for lvl in range(int(levels.max()), -1, -1):
        nodes = np.flatnonzero(levels == lvl)
        if len(nodes) == 0:
            continue
        kids = children[nodes]                        # (m, 4)
        valid = kids >= 0
        kid_idx = np.where(valid, kids, 0)
        live = valid & nonempty[kid_idx]
        n_live = live.sum(axis=1)
        xi_children = np.where(live, xistar[kid_idx], 0.0).sum(axis=1)
        mu = np.where(n_live > 1, xi_children, 0.0)
        sig_children = np.where(live, sigma[kid_idx], 0.0).sum(axis=1) + mu
        v = in_v[nodes]
        take_self = v & ((n_live == 0) | (cost[nodes] <= sig_children))
        take_kids = (~take_self) & (n_live > 0)
        decision[nodes] = np.where(take_self, SELF,
                                   np.where(take_kids, CHILDREN, EMPTY))
        sigma[nodes] = np.where(take_self, cost[nodes],
                                np.where(take_kids, sig_children, 0.0))
        xistar[nodes] = np.where(take_self, xi[nodes],
                                 np.where(take_kids, xi_children, 0.0))
        nonempty[nodes] = take_self | take_kids

    out: list[int] = []
    stack = [0]
    while stack:
        a = stack.pop()
        if decision[a] == SELF:
            out.append(a)
        elif decision[a] == CHILDREN:
            for k in children[a]:
                if k >= 0 and nonempty[k]:
                    stack.append(int(k))
    return np.array(sorted(out), dtype=np.int64)


def brute_force(tree: SQuadTree, in_v: np.ndarray, driven_cs: np.ndarray,
                params: SelectParams = SelectParams()) -> tuple[np.ndarray, float]:
    """Exhaustive search over per-node decisions (tests only, tiny trees).

    Enumerates every antichain expressible by SELF/CHILDREN choices and
    returns (best node set, best cost) under the same hierarchical objective
    the DP optimizes — used to validate Theorem 3.1.
    """
    cost, xi = node_costs(tree, in_v, driven_cs, params)
    children = tree.node_children
    in_v = np.asarray(in_v, dtype=bool)

    def options(a: int) -> list[tuple[tuple[int, ...], float, float]]:
        kids = [int(k) for k in children[a] if k >= 0]
        child_opts = [options(k) for k in kids]
        child_opts = [o for o in child_opts if o]
        outs: list[tuple[tuple[int, ...], float, float]] = []
        if in_v[a]:
            outs.append(((a,), cost[a], xi[a]))
        if child_opts:
            combos = [((), 0.0, 0.0, 0)]
            for opts in child_opts:
                new = []
                for sset, ssig, sxi, nb in combos:
                    for (cs_, csig, cxi) in opts:
                        contributes = 1 if len(cs_) else 0
                        new.append((sset + cs_, ssig + csig, sxi + cxi,
                                    nb + contributes))
                combos = new
            for sset, ssig, sxi, nb in combos:
                mu = sxi if nb > 1 else 0.0
                if len(sset) or not in_v[a]:
                    outs.append((sset, ssig + mu, sxi))
        if not outs and not in_v[a]:
            outs.append(((), 0.0, 0.0))
        # a in V with no children options must pick itself -> already covered
        return outs

    opts = options(0)
    # valid options must cover: if V nonempty the empty set is invalid
    valid = [(s, c, x) for (s, c, x) in opts if len(s) or not in_v.any()]
    best = min(valid, key=lambda t: t[1])
    return np.array(sorted(best[0]), dtype=np.int64), best[1]
