"""Deterministic synthetic token pipeline (LM training substrate).

Host-sharded: each process materializes only its shard of the global batch
(`process_index` / `process_count`), which is how the real-cluster loader
behaves. A Zipf-ish unigram mixture with induced bigram structure gives the
loss something learnable (tests assert the loss actually falls).
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, process_index: int = 0,
                 process_count: int = 1):
        assert global_batch % process_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // process_count
        self.seed = seed
        self.process_index = process_index
        # bigram table: each token prefers a small successor set
        rng = np.random.default_rng(seed)
        self.succ = rng.integers(0, vocab, size=(vocab, 4))

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32, deterministic in (step, shard)."""
        rng = np.random.default_rng(
            (self.seed, step, self.process_index))
        out = np.empty((self.local_batch, self.seq_len + 1), dtype=np.int32)
        # Zipf-ish start tokens
        start = rng.zipf(1.3, size=self.local_batch) % self.vocab
        out[:, 0] = start
        for t in range(1, self.seq_len + 1):
            choice = rng.integers(0, 4, size=self.local_batch)
            noise = rng.random(self.local_batch) < 0.1
            nxt = self.succ[out[:, t - 1], choice]
            nxt = np.where(noise,
                           rng.integers(0, self.vocab, self.local_batch),
                           nxt)
            out[:, t] = nxt
        return out
