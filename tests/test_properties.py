"""Hypothesis property tests on system invariants (random databases)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import join
from repro.core.join import Relation


@st.composite
def relations(draw, max_rows=40):
    n_a = draw(st.integers(1, max_rows))
    n_b = draw(st.integers(1, max_rows))
    dom = draw(st.integers(2, 8))
    a = Relation({
        "x": np.asarray(draw(st.lists(st.integers(0, dom), min_size=n_a,
                                      max_size=n_a)), dtype=np.int64),
        "y": np.asarray(draw(st.lists(st.integers(0, dom), min_size=n_a,
                                      max_size=n_a)), dtype=np.int64),
    })
    b = Relation({
        "x": np.asarray(draw(st.lists(st.integers(0, dom), min_size=n_b,
                                      max_size=n_b)), dtype=np.int64),
        "z": np.asarray(draw(st.lists(st.integers(0, dom), min_size=n_b,
                                      max_size=n_b)), dtype=np.int64),
    })
    return a, b


def _brute_join(a: Relation, b: Relation, on):
    rows = []
    for i in range(a.n):
        for j in range(b.n):
            if all(a[c][i] == b[c][j] for c in on):
                rows.append(tuple(
                    [a[c][i] for c in sorted(a)] +
                    [b[c][j] for c in sorted(b) if c not in a]))
    return sorted(rows)


@given(relations())
@settings(max_examples=60, deadline=None)
def test_sort_merge_join_matches_nested_loop(ab):
    a, b = ab
    got = join.join(a, b)
    cols = sorted(a) + [c for c in sorted(b) if c not in a]
    got_rows = sorted(tuple(int(got[c][i]) for c in cols)
                      for i in range(got.n))
    assert got_rows == _brute_join(a, b, ["x"])


@given(relations())
@settings(max_examples=40, deadline=None)
def test_semijoin_is_join_projection(ab):
    a, b = ab
    semi = join.semijoin(a, b)
    full = join.join(a, b)
    want = {tuple(int(full[c][i]) for c in sorted(a))
            for i in range(full.n)}
    got = {tuple(int(semi[c][i]) for c in sorted(a))
           for i in range(semi.n)}
    assert got == want


@given(st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=60),
       st.lists(st.tuples(st.integers(0, 10 ** 6), st.integers(0, 10 ** 6)),
                min_size=0, max_size=10))
@settings(max_examples=60, deadline=None)
def test_filter_in_ranges_matches_set_semantics(vals, ranges):
    from repro.core.join import filter_in_ranges
    vals_arr = np.asarray(vals, dtype=np.int64)
    rel = Relation({"e": vals_arr})
    intervals = np.asarray([[min(a, b), max(a, b)] for a, b in ranges],
                           dtype=np.int64).reshape(-1, 2)
    explicit = np.asarray(sorted(set(vals[:2])), dtype=np.int64)
    got = filter_in_ranges(rel, "e", intervals, explicit)
    want = [v for v in vals
            if any(lo <= v <= hi for lo, hi in intervals)
            or v in set(explicit.tolist())]
    assert sorted(got["e"].tolist()) == sorted(want)


@given(st.integers(1, 40), st.integers(1, 80), st.integers(1, 8),
       st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_fused_join_equivalent_to_matrix_and_numpy(m, n, k, seed):
    """fused backend ≡ kernel backend ≡ numpy backend candidate pairs, and
    the streaming per-row partials match the dense row-wise top-k."""
    from repro.core import spatial_join
    rng = np.random.default_rng(seed)
    pts_a = rng.random((m, 2))
    pts_b = rng.random((n, 2))
    a = np.concatenate([pts_a, pts_a + rng.random((m, 2)) * 0.05], axis=1)
    b = np.concatenate([pts_b, pts_b + rng.random((n, 2)) * 0.05], axis=1)
    dist = float(rng.uniform(0.01, 0.3))
    ref_i, ref_j = spatial_join.mbr_distance_join(a, b, dist, "numpy")
    for backend in ("kernel", "fused"):
        gi, gj = spatial_join.mbr_distance_join(a, b, dist, backend)
        assert gi.tolist() == ref_i.tolist(), backend
        assert gj.tolist() == ref_j.tolist(), backend
    # per-row partials against the dense oracle
    dk = rng.random(m).astype(np.float32)
    vk = rng.random(n).astype(np.float32)
    gs, gidx = spatial_join.fused_topk_pairs(a, b, dk, vk, dist, k=k,
                                             batch_cols=32)
    from repro.core import geometry
    d = geometry.box_min_dist(a[:, None, :], b[None, :, :])
    bound = np.where(d <= dist, dk[:, None] + vk[None, :], -np.inf)
    want = -np.sort(-bound.astype(np.float32), axis=1)[:, :min(k, n)]
    if want.shape[1] < k:
        want = np.pad(want, ((0, 0), (0, k - want.shape[1])),
                      constant_values=-np.inf)
    np.testing.assert_allclose(gs, want, rtol=1e-6, atol=1e-6)


@given(st.integers(10, 200), st.integers(1, 20), st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_topk_threshold_monotone(n, k, seed):
    from repro.core.topk import TopK
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n)
    tk = TopK(k=k)
    thetas = []
    for i in range(0, n, 7):
        chunk = scores[i:i + 7]
        tk.push(chunk, Relation({"r": np.arange(len(chunk), dtype=np.int64)}))
        thetas.append(tk.theta)
    # theta is monotonically non-decreasing (descending mode)
    assert all(b >= a - 1e-12 for a, b in zip(thetas, thetas[1:]))
    got, _ = tk.results()
    want = np.sort(scores)[::-1][:k]
    np.testing.assert_allclose(np.sort(got)[::-1], want)
