"""Pallas TPU kernel: GQA flash attention (forward).

Online-softmax tiling [FlashAttention, arXiv:2205.14135] adapted to the TPU
memory hierarchy: Q/K/V tiles staged HBM->VMEM by BlockSpec, the (bq, bk)
logit tile lives only in VMEM/VREGs, and the running (m, l, acc) state sits
in VMEM scratch carried across the kv grid dimension (TPU grids iterate the
trailing axis innermost, so `nk` is the reduction axis). GQA is expressed in
the K/V index_map: kv_head = q_head // group, so no K/V repeat is ever
materialized. MXU-aligned tiles: bq, bk multiples of 128 where shapes allow.

Training uses XLA's fused attention (this kernel is forward-only); the serve
path and prefill use this kernel on real TPUs. Validation: interpret=True
against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool, bq: int, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(1)
    run = True
    if causal:
        # skip fully-masked tiles (query block strictly above diagonal)
        run = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _final():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, S, D); k, v (B, Hkv, S, D); Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    g = hq // hkv
    scale = d ** -0.5
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, "seq must divide block sizes"
    nq, nk = s // bq, s // bk
    qf = q.reshape(b * hq, s, d)
    grid = (b * hq, nq, nk)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        batch = h // hq
        kvh = (h % hq) // g
        return (batch * hkv + kvh, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k.reshape(b * hkv, s, d), v.reshape(b * hkv, s, d))
    return out.reshape(b, hq, s, d)
