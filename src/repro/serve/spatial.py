"""Multi-tenant spatial-query serving: continuous batching over STREAK.

The LM decode loop in serve/engine.py generalizes directly: a fixed pool of
`max_slots` slots, each holding one query's `QueryCursor`; waiting requests
claim free slots, every engine step advances EVERY active slot by one driver
block, and a query that θ-terminates (or exhausts its driver scan) releases
its slot mid-flight for the next queued request — continuous batching, with
"one decoded token" replaced by "one driver block".

What actually batches across tenants per step:

- **Phases 1-2** — every slot's `begin_block()` request is pooled into ONE
  `candidate_nodes` call (per-block driven-CS sets + per-block distances;
  slots of the same query shape share Bloom probes) and ONE `select_batch`
  call with a stacked per-row cost matrix.
- **Phase 3** — with the fused join backend, every slot's streaming join
  registers with a `_FusedJoinBatcher`; one `fused_stream_join_multi` run
  then launches all live queries' driver blocks in shared kernel grids with
  per-row (distance, θ, query-id) state, each query's partial results
  feeding back into its own TopK between launches.

θ pruning is sound at any batching granularity, so per-query results are
bit-identical to serial `StreakEngine.execute` runs — the stress tests
assert exactly that.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import node_select, spatial_join
from ..core.executor import ExecStats, QueryCursor, StreakEngine
from ..core.join import Relation
from ..core.query import Query


@dataclasses.dataclass
class SpatialRequest:
    rid: int
    query: Query
    scores: np.ndarray | None = None
    rows: Relation | None = None
    stats: ExecStats | None = None
    done: bool = False
    steps: int = 0                  # engine steps this request stayed active
    waited: int = 0                 # engine steps spent queued


@dataclasses.dataclass
class ServeStats:
    steps: int = 0                  # engine iterations
    admissions: int = 0             # slot claims (== completed requests)
    released_early: int = 0         # slots freed by θ termination mid-scan
    slot_reuse: int = 0             # admissions beyond the first per slot
    sip_batches: int = 0            # pooled candidate_nodes/select calls
    sip_blocks: int = 0             # driver blocks covered by those calls
    join_launches: int = 0          # cross-query fused kernel launches
    max_queue: int = 0


class _FusedJoinBatcher:
    """Collects every slot's Phase-3 streaming join for one engine step and
    runs them as cross-query `fused_stream_join_multi` launches."""

    def __init__(self, batch_cols: int, tuner=None):
        self.batch_cols = batch_cols
        self.tuner = tuner
        self.entries: list[spatial_join.StreamEntry] = []

    def add(self, entry: spatial_join.StreamEntry) -> None:
        self.entries.append(entry)

    def flush(self) -> int:
        if not self.entries:
            return 0
        launches = spatial_join.fused_stream_join_multi(
            self.entries, batch_cols=self.batch_cols, tuner=self.tuner)
        self.entries = []
        return launches


class SpatialServeEngine:
    """Slot-based admission loop over a shared `StreakEngine`.

    One engine instance per store: the relation scan cache, the Bloom
    `PreparedKeys`, and the kcap autotuner are shared by every tenant.
    """

    def __init__(self, store, config=None, max_slots: int = 8):
        self.engine = StreakEngine(store, config)
        # tenants running the same query shape (a hot query with per-user
        # k, say) share θ-independent per-block work: driver-block
        # materialization, S-Plan filtered retrieval, N-Plan block joins
        # (executor.StreakEngine.share_cache) and pooled Phase-1/2 rows
        # (deduped in step()). Serial per-query execution recomputes all
        # of it per tenant.
        self.engine.share_cache = {}
        self.max_slots = max_slots
        self.slots: list[tuple[SpatialRequest, QueryCursor] | None] = \
            [None] * max_slots
        self.queue: list[SpatialRequest] = []
        self.stats = ServeStats()
        self._slot_used = [False] * max_slots

    # ------------------------------------------------------------------
    def submit(self, req: SpatialRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = (req, self.engine.cursor(req.query))
                self.stats.admissions += 1
                if self._slot_used[slot]:
                    self.stats.slot_reuse += 1
                self._slot_used[slot] = True

    def _retire(self, slot: int) -> None:
        req, cur = self.slots[slot]
        req.scores, req.rows, req.stats = cur.results()
        req.done = True
        if cur.stats.early_terminated:
            self.stats.released_early += 1
        self.slots[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One iteration: admit, advance every active slot one driver block
        (Phases 1-2 pooled, Phase 3 cross-query batched), retire finished
        queries. Returns the number of active slots this step."""
        self._admit()
        self.stats.max_queue = max(self.stats.max_queue, len(self.queue))
        active = [s for s in range(self.max_slots)
                  if self.slots[s] is not None]
        if not active:
            return 0
        self.stats.steps += 1
        for s in active:
            self.slots[s][0].steps += 1
        for r in self.queue:
            r.waited += 1

        # ---- phase A: materialize one block per slot, pool SIP requests --
        work: list[tuple[int, dict]] = []        # (slot, request)
        for s in active:
            req, cur = self.slots[s]
            sip_req = cur.begin_block()
            if sip_req is None:                  # finished (θ or exhausted)
                self._retire(s)
                continue
            work.append((s, sip_req))

        sip_slots = [(s, r) for (s, r) in work if r["need_sip"]]
        v_stars: dict[int, list | None] = {s: None for (s, r) in work}
        if sip_slots:
            # one pooled Phase-1/2 call over every tenant's window rows;
            # rows of one tenant share a CS array (and thus one frontier
            # group), different tenants' groups ride the same batch, and
            # identical rows from same-shape tenants collapse to one row
            tree = self.engine.store.tree
            policy = self.engine.config.policy
            boxes, cs_sets, prepared, dists, cards = [], [], [], [], []
            cs_paths = []
            row_of: dict[tuple, int] = {}
            spans: list[tuple[int, list[int]]] = []
            for s, r in sip_slots:
                cs_bytes = np.asarray(r["driven_cs"]).tobytes()
                rows = []
                for box in r["boxes"]:
                    box = box if box is not None else np.zeros((0, 4))
                    rk = (box.shape, box.tobytes(), cs_bytes,
                          float(r["dist_norm"]))
                    idx = row_of.get(rk)
                    if idx is None:
                        idx = len(boxes)
                        row_of[rk] = idx
                        boxes.append(box)
                        cs_sets.append(r["driven_cs"])
                        prepared.append(r["prepared"])
                        dists.append(r["dist_norm"])
                        cards.append(r["card_all"])
                        # tenants' precomputed root-path masks ride along so
                        # fused descents skip the per-step Bloom probes
                        cs_paths.append(r.get("cs_path"))
                    rows.append(idx)
                spans.append((s, rows))
            in_v = tree.candidate_nodes(boxes, np.array(dists), cs_sets,
                                        prepared=prepared,
                                        probe_backend=policy.probe,
                                        descend_backend=policy.descend,
                                        cs_path=cs_paths)
            sel = node_select.select_batch(
                tree, in_v, cs_sets, self.engine.config.select_params,
                card_all=np.stack(cards))
            for s, rows in spans:
                v_stars[s] = [sel[i] for i in rows]
            self.stats.sip_batches += 1
            self.stats.sip_blocks += len(boxes)

        # ---- phase B: APS + driven retrieval + Phase-3 -------------------
        batcher = None
        if self.engine.config.policy.join == "fused" \
                and self.engine.config.mbr_join_fn is None:
            batcher = _FusedJoinBatcher(self.engine.config.fused_batch_cols,
                                        tuner=self.engine.kcap_tuner)
        for s, _ in work:
            req, cur = self.slots[s]
            cur.finish_block(v_stars[s], batcher=batcher)
        if batcher is not None:
            self.stats.join_launches += batcher.flush()
        for s, _ in work:
            if self.slots[s][1].done:
                self._retire(s)
        # bound the cross-tenant memo (entries hold relations); sharing is
        # overwhelmingly within-step, so a coarse reset loses little
        sc = self.engine.share_cache
        if sc is not None and len(sc) > 1024:
            sc.clear()
        return len(active)

    def run(self) -> None:
        while self.queue or any(sl is not None for sl in self.slots):
            if self.step() == 0 and not self.queue:
                break

    # ------------------------------------------------------------------
    def serve(self, queries: list[Query]) -> list[SpatialRequest]:
        """Convenience: submit all, run to completion, return requests in
        submission order."""
        reqs = [SpatialRequest(rid=i, query=q) for i, q in enumerate(queries)]
        for r in reqs:
            self.submit(r)
        self.run()
        return reqs
