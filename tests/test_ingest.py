"""Real-format ingestion round-trip: GTFS-flavored stops CSV -> QuadStore
-> every query shape, bit-identical to the brute-force oracle, with the
original values recoverable through the dictionary."""
import os

import numpy as np
import pytest

from repro.core.baselines import FullScanEngine
from repro.core.executor import StreakEngine
from repro.core.query import Query, Ranking, SpatialFilter, TriplePattern, Var
from repro.data import ingest

SAMPLE = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                      "data", "samples", "gtfs_stops.csv")


@pytest.fixture(scope="module")
def ds():
    return ingest.build_stops_store(SAMPLE)


def _stop_patterns(ns, suffix=""):
    s, g = Var(f"stop{suffix}"), Var(f"geo{suffix}")
    return s, g, (
        TriplePattern(s, ns["rdf:type"], ns["gtfs:Stop"], g=Var(f"r{suffix}")),
        TriplePattern(s, ns["hasGeometry"], g),
    )


# ----------------------------------------------------------- CSV parsing --
def test_parse_rejects_malformed():
    with pytest.raises(ValueError, match="missing required"):
        ingest.parse_stops_text("stop_id,stop_name\nS1,A\n")
    with pytest.raises(ValueError, match="duplicate stop_id"):
        ingest.parse_stops_text(
            "stop_id,stop_name,stop_lat,stop_lon\nS1,A,1,2\nS1,B,3,4\n")
    with pytest.raises(ValueError, match="unparseable"):
        ingest.parse_stops_text(
            "stop_id,stop_name,stop_lat,stop_lon\nS1,A,north,2\n")
    with pytest.raises(ValueError, match="empty"):
        ingest.parse_stops_text("stop_id,stop_name,stop_lat,stop_lon\n")


def test_column_classification(ds):
    assert ds.numeric_columns == ("zone_fare", "daily_boardings")
    assert ds.string_columns == ("zone_id",)
    assert ds.n_stops == 40


# ------------------------------------------------------------ round trip --
def test_roundtrip_values_and_geometry(ds):
    store, ns = ds.store, ds.ns
    d = store.dictionary
    rows = ingest.parse_stops_csv(SAMPLE)
    for row in rows[:10] + rows[-5:]:
        e = d.term_to_id[f"stop:{row['stop_id']}"]
        # geometry round-trips through the f32 pool
        prow = store.geom_rows(np.array([e], dtype=np.int64))[0]
        pt = store.geom_pool.points[store.geom_pool.offsets[prow]]
        assert pt[0] == np.float32(row["stop_lon"])
        assert pt[1] == np.float32(row["stop_lat"])
        # numeric cells round-trip through the numeric side table
        v = (row.get("daily_boardings") or "").strip()
        quads = store.scan(s=int(e), p=int(ns["gtfs:daily_boardings"]))
        if v:
            assert len(quads) == 1
            assert d.numeric_value[int(quads[0, 3])] == float(v)
        else:
            assert len(quads) == 0  # blank cell -> no fact (open world)


def test_numeric_columns_are_rankable(ds):
    """Ingested numeric predicates drive the paper's top-k machinery:
    directed numeric indexes exist and ORDER BY works end-to-end."""
    store, ns = ds.store, ds.ns
    assert int(ns["gtfs:daily_boardings"]) in store.numeric
    assert int(ns["gtfs:zone_fare"]) in store.numeric
    s, g, pats = _stop_patterns(ns)
    s2, g2, pats2 = _stop_patterns(ns, "2")
    board = Var("board")
    q = Query(select=(s, s2),
              patterns=pats + pats2
              + (TriplePattern(s, ns["gtfs:daily_boardings"], board),),
              spatial=SpatialFilter(g, g2, 0.01),
              ranking=Ranking(((board, 1.0),), descending=True), k=7)
    es, erows, _ = StreakEngine(store).execute(q)
    bs, brows, _ = FullScanEngine(store).execute(q)
    np.testing.assert_array_equal(es, bs)
    assert len(es) == 7
    assert np.all(np.diff(es) <= 0)


@pytest.mark.parametrize("spatial", [
    SpatialFilter(Var("geo"), None,
                  window=(-122.42, 37.78, -122.39, 37.80)),
    SpatialFilter(Var("geo"), None, dist=0.02,
                  center=(-122.4075, 37.7880)),
    SpatialFilter(Var("geo"), Var("geo2"), dist=0.005),
    SpatialFilter(Var("geo"), Var("geo2"), knn=3),
], ids=["range", "within", "join", "knn"])
def test_ingested_shapes_match_oracle(ds, spatial):
    store, ns = ds.store, ds.ns
    s, g, pats = _stop_patterns(ns)
    if spatial.b is not None:
        s2, g2, pats2 = _stop_patterns(ns, "2")
        pats = pats + pats2
        select = (s, s2)
    else:
        select = (s,)
    q = Query(select=select, patterns=pats, spatial=spatial, ranking=None)
    es, erows, _ = StreakEngine(store).execute(q)
    os_, orows, _ = FullScanEngine(store).execute(q)
    np.testing.assert_array_equal(es, os_)
    assert sorted(erows.keys()) == sorted(orows.keys())
    for c in orows.keys():
        np.testing.assert_array_equal(erows[c], orows[c])


def test_coincident_stops_within_zero(ds):
    """S034/S035 share coordinates; dist=0 at their f32-stored point must
    return BOTH with exactly-zero scores (engine == oracle)."""
    store, ns = ds.store, ds.ns
    d = store.dictionary
    e = d.term_to_id["stop:S034"]
    prow = store.geom_rows(np.array([e], dtype=np.int64))[0]
    pt = store.geom_pool.points[store.geom_pool.offsets[prow]].astype(float)
    s, g, pats = _stop_patterns(ns)
    q = Query(select=(s,), patterns=pats,
              spatial=SpatialFilter(g, None, dist=0.0,
                                    center=(pt[0], pt[1])),
              ranking=None)
    es, erows, _ = StreakEngine(store).execute(q)
    os_, orows, _ = FullScanEngine(store).execute(q)
    np.testing.assert_array_equal(es, os_)
    got = sorted(d.lookup(int(x)) for x in np.unique(erows["stop"]))
    assert got == ["stop:S034", "stop:S035"]
    np.testing.assert_array_equal(es, np.zeros(len(es)))


def test_blank_numeric_cells_drop_from_ranking(ds):
    """S038 has no daily_boardings fact: it simply never appears in a
    ranking over that predicate (NaN-score drop), engine == baseline."""
    store, ns = ds.store, ds.ns
    s, g, pats = _stop_patterns(ns)
    s2, g2, pats2 = _stop_patterns(ns, "2")
    board = Var("board")
    q = Query(select=(s, s2),
              patterns=pats + pats2
              + (TriplePattern(s, ns["gtfs:daily_boardings"], board),),
              spatial=SpatialFilter(g, g2, 0.5),
              ranking=Ranking(((board, 1.0),), descending=False), k=10 ** 6)
    es, erows, _ = StreakEngine(store).execute(q)
    bs, brows, _ = FullScanEngine(store).execute(q)
    np.testing.assert_array_equal(np.sort(es), np.sort(bs))
    missing = store.dictionary.term_to_id["stop:S038"]
    assert missing not in set(np.unique(erows["stop"]).tolist())
