"""Serving launcher: continuous-batching LM decode or STREAK retrieval.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \
        --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch sasrec --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import registry


def serve_lm(mod, cfg, n_requests: int) -> None:
    from ..models import moe as moe_m, transformer as tr
    from ..serve.engine import Request, ServeEngine
    m = moe_m if mod.FAMILY == "moe" else tr
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(m, params, cfg, max_batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, 4).tolist(),
                    max_new=8) for i in range(n_requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    eng.run()
    dt = time.time() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"served {n_requests} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, continuous batching over 4 slots)")


def serve_retrieval(cfg) -> None:
    from ..models import sasrec
    from ..serve import retrieval
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # popularity-skewed catalog (trained norms track popularity)
    pop = jnp.asarray(np.log1p(rng.zipf(1.4, cfg.n_items).clip(1, 1000))
                      .astype(np.float32))
    params["item_embed"] = params["item_embed"] * pop[:, None]
    seq = jnp.asarray(rng.integers(1, cfg.n_items, (8, cfg.seq_len)),
                      jnp.int32)
    state = sasrec.user_state(params, seq, cfg)
    block = max(64, cfg.n_items // 16)
    items_s, order = retrieval.sort_items_by_norm(params["item_embed"], block)
    bounds = retrieval.block_bounds(items_s, block)
    t0 = time.time()
    scores, ids, blocks_read = retrieval.streak_topk(
        state, items_s, order.astype(jnp.int32), bounds, k=10, block=block)
    nb = bounds.shape[0]
    print(f"STREAK retrieval: top-10 for 8 users over {cfg.n_items} items "
          f"in {time.time()-t0:.2f}s; early-out read {int(blocks_read)}/{nb} "
          f"blocks")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()
    mod = registry.get(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    if mod.FAMILY in ("lm", "moe"):
        serve_lm(mod, cfg, args.requests)
    elif mod.FAMILY == "recsys":
        serve_retrieval(cfg)
    else:
        raise SystemExit(f"no serve path for family {mod.FAMILY}")


if __name__ == "__main__":
    main()
