"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. See DESIGN.md §6 for the
paper-artifact -> benchmark index.

``--json`` additionally writes one ``BENCH_<suite>.json`` per suite run
(e.g. ``BENCH_refine.json``, ``BENCH_join.json``, ``BENCH_sip.json``) into
the current directory — the perf trajectory future changes are compared
against. ``python -m benchmarks.run sip --json`` refreshes the Phase 1-2
trajectory after touching the SIP path.
"""
from __future__ import annotations

import json
import sys
import time


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    from . import (bench_aps, bench_engines, bench_geo, bench_join,
                   bench_kernels, bench_refine, bench_serve, bench_sip,
                   bench_sizes, bench_vary_k)
    suites = [
        ("table1/3 sizes", bench_sizes),
        ("fig7 SIP", bench_sip),
        ("fig8 join algorithms", bench_join),
        ("fig9 APS", bench_aps),
        ("fig10/11 engines", bench_engines),
        ("fig12 vary k", bench_vary_k),
        ("refinement", bench_refine),
        ("kernels", bench_kernels),
        ("serving", bench_serve),
        ("geographica shapes", bench_geo),
    ]
    args = [a for a in sys.argv[1:] if a != "--json"]
    write_json = "--json" in sys.argv[1:]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for label, mod in suites:
        if only and only not in label and only not in mod.__name__:
            continue
        t0 = time.time()
        rows = []
        for row in mod.run():
            print(row)
            rows.append(row)
        print(f"# {label}: {time.time()-t0:.1f}s", file=sys.stderr)
        if write_json:
            short = mod.__name__.rsplit(".", 1)[-1].removeprefix("bench_")
            path = f"BENCH_{short}.json"
            with open(path, "w") as fh:
                json.dump([_parse_row(r) for r in rows], fh, indent=1)
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
