"""Query representation (§2): graph patterns + spatial filter + top-k ranking.

    SELECT [projection] WHERE [patterns] FILTER [distance(a,b) < d]
    ORDER BY [ranking] LIMIT [k]

Reified statements are plain quad patterns with a bound/variable `g` slot
(``?r rdf:subject ?s . ?r rdf:predicate ?p . ?r rdf:object ?o`` collapses to
one quad pattern with g = ?r).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __repr__(self):
        return f"?{self.name}"


Term = "int | Var"


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: object
    p: object
    o: object
    g: object = None   # None = don't-care, Var = reification id, int = bound

    def vars(self) -> list[Var]:
        return [t for t in (self.g, self.s, self.p, self.o) if isinstance(t, Var)]

    def n_bound(self) -> int:
        return sum(1 for t in (self.g, self.s, self.p, self.o)
                   if t is not None and not isinstance(t, Var))


@dataclasses.dataclass(frozen=True)
class SpatialFilter:
    """Spatial predicate over geometry variables, in world units.

    The binary form is the paper's FILTER(distance(?a, ?b) < dist). The
    Geographica-shaped extensions reuse the same carrier:

    - ``window=(xmin, ymin, xmax, ymax)`` — spatial *range*: ?a's exact
      geometry has a point inside the (closed) window. Unary (``b=None``).
    - ``center=(x, y)`` — *within-distance*: min distance from ?a's exact
      geometry to the point is <= ``dist``. Unary (``b=None``).
    - ``knn=k`` — per-?a-entity k nearest ?b entities by exact geometry
      distance (short lists allowed when fewer than k candidates exist).
    - binary, no ranking on the query — non-top-k *spatial join*: every
      (?a, ?b) pair within ``dist``.
    """
    a: Var
    b: Var | None = None
    dist: float = 0.0
    metric: str = "euclid"   # or "haversine"
    window: tuple | None = None   # (xmin, ymin, xmax, ymax) world coords
    center: tuple | None = None   # (x, y) world coords
    knn: int | None = None        # per-driver-entity k

    def shape(self) -> str:
        """One of "range", "within", "knn", "join", "topk"."""
        if self.window is not None:
            return "range"
        if self.center is not None:
            return "within"
        if self.knn is not None:
            return "knn"
        return "topk"   # binary; Query.shape() downgrades to "join"


@dataclasses.dataclass(frozen=True)
class Ranking:
    """ORDER BY sum_i w_i * value(?v_i); descending = True for DESC."""
    terms: tuple            # ((Var, weight), ...)
    descending: bool = True

    def vars(self) -> list[Var]:
        return [v for v, _ in self.terms]


@dataclasses.dataclass(frozen=True)
class Query:
    select: tuple
    patterns: tuple
    spatial: SpatialFilter | None
    ranking: Ranking | None
    k: int = 100

    def shape(self) -> str:
        """Query shape: "topk" (paper §2), "range", "within", "knn", or
        "join" (binary spatial filter without a ranking = non-top-k
        spatial join). Selection shapes ignore `ranking`/`k`; "knn" takes
        its per-driver k from ``spatial.knn``."""
        if self.spatial is None:
            return "scan"
        s = self.spatial.shape()
        if s == "topk" and self.ranking is None:
            return "join"
        return s

    def all_vars(self) -> list[Var]:
        seen, out = set(), []
        for tp in self.patterns:
            for v in tp.vars():
                if v.name not in seen:
                    seen.add(v.name)
                    out.append(v)
        return out
