"""Relational-path property tests: the two-phase merge join vs the oracles.

The jitted merge `join` / `semijoin` / `filter_in_ranges`
(core/join.py, rank pass dispatched through kernels/ops.merge_join_ranks)
must be *bit-identical* — same rows, same order — to the pre-rework numpy
`*_looped` oracles across duplicate-key, empty-relation, skewed-multiplicity,
single-column, and overflow-domain inputs, on every dispatch backend:
the numpy searchsorted oracle, the jitted CPU twin, the dense jnp kernel
route, and the interpret-mode Pallas kernel.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import join as J
from repro.core.join import Relation
from repro.kernels import merge_join as mj
from repro.kernels import ops as kops
from repro.kernels import ref

# "numpy" = searchsorted oracle; "cpu" = jitted loop-structured twin;
# "kernel" = dense jnp route (Pallas-native on TPU); "interpret" = Pallas
# kernel in interpret mode
BACKENDS = ("numpy", "cpu", "kernel", "interpret")


def _assert_rel_identical(got: Relation, want: Relation):
    assert set(got) == set(want)
    assert got.n == want.n
    for c in want:
        np.testing.assert_array_equal(got[c], want[c])


@st.composite
def relation_pairs(draw):
    """Joinable relation pairs over the corner regimes: duplicate-heavy
    (dom=1..3), skewed multiplicity (a hot key on both sides), empty
    relations, single- vs multi-column keys, and id domains wide enough to
    force the composite-key dense-rank fallbacks (2^40 per column hits the
    per-column ranking on 2+ columns; 2^60 leaves a ~2^60 first-column
    scale, so the second column also forces the accumulated-prefix
    re-rank)."""
    seed = draw(st.integers(0, 2 ** 32 - 1))
    n_a = draw(st.integers(0, 48))
    n_b = draw(st.integers(0, 48))
    n_cols = draw(st.integers(1, 3))
    dom = draw(st.sampled_from([1, 3, 16, 1 << 40, 1 << 60]))
    hot = draw(st.floats(0.0, 1.0))
    rng = np.random.default_rng(seed)

    def side(n, extra):
        rel = Relation()
        for c in ("x", "y", "z")[:n_cols]:
            v = rng.integers(0, dom, n).astype(np.int64)
            v[rng.random(n) < hot] = np.int64(dom // 2)   # skewed key
            rel[c] = v
        rel[extra] = rng.integers(0, 5, n).astype(np.int64)
        return rel

    return side(n_a, "a_only"), side(n_b, "b_only")


@given(relation_pairs())
@settings(max_examples=30, deadline=None)
def test_join_bit_identical_all_backends(pair):
    a, b = pair
    want = J.join_looped(a, b)
    for backend in BACKENDS:
        _assert_rel_identical(J.join(a, b, backend=backend), want)
    _assert_rel_identical(J.join(a, b, impl="looped"), want)


@given(relation_pairs())
@settings(max_examples=30, deadline=None)
def test_semijoin_bit_identical_all_backends(pair):
    a, b = pair
    want = J.semijoin_looped(a, b)
    for backend in BACKENDS:
        _assert_rel_identical(J.semijoin(a, b, backend=backend), want)


@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 40), st.integers(0, 6),
       st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_filter_in_ranges_bit_identical_all_backends(seed, n, n_iv, n_ex):
    rng = np.random.default_rng(seed)
    rel = Relation({"e": rng.integers(0, 100, n).astype(np.int64),
                    "v": rng.integers(0, 5, n).astype(np.int64)})
    iv = rng.integers(0, 100, (n_iv, 2)).astype(np.int64)
    iv.sort(axis=1)                               # closed [lo, hi] rows
    ex = np.unique(rng.integers(0, 100, n_ex).astype(np.int64))
    want = J.filter_in_ranges_looped(rel, "e", iv, ex)
    for backend in BACKENDS:
        _assert_rel_identical(
            J.filter_in_ranges(rel, "e", iv, ex, backend=backend), want)


# ------------------------------------------------------------- edge cases --
def test_empty_and_cartesian_edges():
    a = Relation({"x": np.array([1, 2], dtype=np.int64)})
    b = Relation({"y": np.array([7], dtype=np.int64)})
    empty = Relation.empty(["x"])
    for impl in ("merge", "looped"):
        cart = J.join(a, b, impl=impl)            # no shared vars
        assert cart.n == 2 and set(cart) == {"x", "y"}
        assert J.join(a, empty, impl=impl).n == 0
        assert J.join(empty, a, impl=impl).n == 0
        assert J.semijoin(empty, a, impl=impl).n == 0
        _assert_rel_identical(J.semijoin(a, empty.take(np.empty(0, np.int64)),
                                         on=[], impl=impl), a)
    # no intervals and no explicit ids -> SIP eliminates every row
    assert J.filter_in_ranges(a, "x", np.empty((0, 2), np.int64),
                              np.empty(0, np.int64)).n == 0


def test_unknown_impl_and_backend_raise():
    a = Relation({"x": np.array([1], dtype=np.int64)})
    with pytest.raises(ValueError):
        J.join(a, a, impl="bogus")
    with pytest.raises(ValueError):
        kops.merge_join_ranks(np.array([1]), np.array([1]), backend="bogus")
    with pytest.raises(ValueError):
        kops.merge_join_ranks(np.array([1]), np.array([1]), side="middle")


# ------------------------------------------------------- composite keys ----
@given(relation_pairs())
@settings(max_examples=30, deadline=None)
def test_composite_keys_order_isomorphic(pair):
    """Packed scalars compare exactly like the column tuples."""
    a, b = pair
    on = sorted(set(a) & set(b))
    if a.n == 0 or b.n == 0:
        return
    ka, kb, scale = J.composite_keys(a, b, on)
    rows_a = list(zip(*(a[c] for c in on)))
    rows_b = list(zip(*(b[c] for c in on)))
    both_keys = np.concatenate([ka, kb])
    both_rows = rows_a + rows_b
    assert both_keys.min() >= 0 and int(both_keys.max()) < scale
    order = np.argsort(both_keys, kind="stable")
    for i, j in zip(order[:-1], order[1:]):
        assert both_rows[i] <= both_rows[j]
        assert (both_keys[i] == both_keys[j]) == (both_rows[i] == both_rows[j])


@given(st.integers(0, 2 ** 32 - 1), st.integers(1, 200),
       st.sampled_from([4, 1 << 8, 1 << 55]))
@settings(max_examples=30, deadline=None)
def test_sort_with_perm_matches_stable_argsort(seed, n, dom):
    """Both branches (index-packed np.sort and the argsort fallback) return
    the stable permutation; dom=2^55 with n free low bits forces the
    fallback."""
    rng = np.random.default_rng(seed)
    k = rng.integers(0, dom, n).astype(np.int64)
    ks, perm = J._sort_with_perm(k, dom)
    want = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(perm, want)
    np.testing.assert_array_equal(ks, k[want])


# ----------------------------------------------------------- rank pass -----
@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 300), st.integers(0, 200),
       st.sampled_from([8, 1 << 20, 1 << 62]))
@settings(max_examples=25, deadline=None)
def test_rank_backends_match_searchsorted(seed, n, m, dom):
    """All rank backends equal np.searchsorted on sorted int64 tables,
    including negative keys and magnitudes crossing the 32-bit plane split."""
    rng = np.random.default_rng(seed)
    table = np.sort(rng.integers(-dom, dom, n).astype(np.int64))
    probes = rng.integers(-dom, dom, m).astype(np.int64)
    want_lo = np.searchsorted(table, probes, "left")
    want_hi = np.searchsorted(table, probes, "right")
    for backend in BACKENDS:
        lo, hi = kops.merge_join_ranks(table, probes, backend=backend)
        np.testing.assert_array_equal(lo, want_lo)
        np.testing.assert_array_equal(hi, want_hi)
        np.testing.assert_array_equal(
            kops.merge_join_ranks(table, probes, backend=backend,
                                  side="left"), want_lo)
        np.testing.assert_array_equal(
            kops.merge_join_ranks(table, probes, backend=backend,
                                  side="right"), want_hi)


def test_rank_kernel_grid_and_padding_sweep():
    """Interpret-mode kernel vs the dense ref across probe blocks crossing
    grid boundaries and tables crossing the 128-lane padding boundary."""
    rng = np.random.default_rng(0)
    for n, m, bb in ((1, 1, 8), (127, 20, 8), (128, 24, 8), (129, 9, 8),
                     (300, 70, 64), (5, 200, 64)):
        table = np.sort(rng.integers(-(1 << 50), 1 << 50, n).astype(np.int64))
        probes = np.concatenate([
            rng.integers(-(1 << 50), 1 << 50, m - m // 2).astype(np.int64),
            rng.choice(table, m // 2)])           # exact hits incl. dups
        t_hi, t_lo = kops.split_key_planes(table)
        p_hi, p_lo = kops.split_key_planes(probes)
        want_lo, want_hi = ref.merge_join_ranks_ref(t_hi, t_lo, p_hi, p_lo)
        lo, hi = mj.merge_join_ranks(t_hi, t_lo, p_hi, p_lo, bb=bb,
                                     interpret=True)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(want_lo))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(want_hi))
        np.testing.assert_array_equal(np.asarray(want_lo),
                                      np.searchsorted(table, probes, "left"))
        host_lo, host_hi = mj.merge_join_ranks_host(t_hi, t_lo, p_hi, p_lo)
        np.testing.assert_array_equal(np.asarray(host_lo), np.asarray(want_lo))
        np.testing.assert_array_equal(np.asarray(host_hi), np.asarray(want_hi))


# --------------------------------------- sortedness + packed-key caching ----
def test_sorted_by_fast_path_bit_identical():
    """A relation marked sorted by the join key skips the argsort via the
    identity permutation — outputs must match the unmarked run exactly,
    and the join output must carry the key mark itself."""
    rng = np.random.default_rng(5)
    xs = np.sort(rng.integers(0, 50, 400))
    ps = rng.integers(0, 9, 400)
    b = Relation({"x": rng.integers(0, 50, 300), "q": rng.integers(0, 9, 300)})
    marked = Relation({"x": xs, "p": ps})
    marked.sorted_by = ("x",)
    plain = Relation({"x": xs.copy(), "p": ps.copy()})
    out_m, out_p = J.join(marked, b), J.join(plain, b)
    assert out_m.keys() == out_p.keys()
    for c in out_p:
        np.testing.assert_array_equal(out_m[c], out_p[c])
    assert out_p.sorted_by == ("x",)
    # column (re)assignment must conservatively drop the mark
    out_p["p"] = out_p["p"].copy()
    assert out_p.sorted_by == ()


def test_keycache_warm_replay_and_window_fallback():
    """Packed-key cache: a second join reusing one side must replay the
    cached pack when the partner's values fit the packing window, fall back
    to a joint repack when they don't — bit-identical either way."""
    rng = np.random.default_rng(6)
    a = Relation({"x": rng.integers(0, 40, 600), "p": rng.integers(0, 5, 600)})
    b = Relation({"x": rng.integers(0, 40, 500), "q": rng.integers(0, 5, 500)})
    in_win = Relation({"x": rng.integers(0, 40, 300),
                       "r": rng.integers(0, 5, 300)})
    out_win = Relation({"x": rng.integers(-900, 900, 300),
                        "r": rng.integers(0, 5, 300)})
    np_cold = {k: J.join_looped(a, v) for k, v in
               (("b", b), ("in", in_win), ("out", out_win))}
    warm_b = J.join(a, b)                       # populates a's pack cache
    assert getattr(a, "_keycache", None), "first merge join must cache packs"
    warm_in = J.join(a, in_win)                 # replays the cached pack
    warm_out = J.join(a, out_win)               # window miss -> joint repack
    for got, want in ((warm_b, np_cold["b"]), (warm_in, np_cold["in"]),
                      (warm_out, np_cold["out"])):
        assert got.keys() == want.keys()
        for c in want:
            np.testing.assert_array_equal(got[c], want[c])
    # mutation invalidates the cache (stale packs would be unsound)
    a["x"] = a["x"].copy()
    assert not getattr(a, "_keycache", None)


def test_keycache_lru_entry_budget_and_touch(monkeypatch):
    """The per-Relation pack cache is bounded: beyond the entry budget the
    least-recently-used packing is evicted, a cache hit refreshes recency,
    and every join stays bit-identical while entries churn."""
    monkeypatch.setattr(J, "KEYCACHE_MAX_ENTRIES", 3)
    rng = np.random.default_rng(7)
    a = Relation({f"x{i}": rng.integers(0, 30, 200) for i in range(5)})
    partners = [Relation({f"x{i}": rng.integers(0, 30, 80),
                          f"p{i}": rng.integers(0, 5, 80)})
                for i in range(5)]
    want = [J.join_looped(a, p) for p in partners]

    def check(i):
        got = J.join(a, partners[i])
        assert got.keys() == want[i].keys()
        for c in want[i]:
            np.testing.assert_array_equal(got[c], want[i][c])

    for i in range(3):
        check(i)
    assert list(a._keycache) == [("x0",), ("x1",), ("x2",)]
    check(3)                                    # over budget: x0 is LRU, out
    assert list(a._keycache) == [("x1",), ("x2",), ("x3",)]
    check(1)                                    # hit: x1 moves to recent end
    assert list(a._keycache) == [("x2",), ("x3",), ("x1",)]
    check(4)                                    # now x2 is the LRU victim
    assert list(a._keycache) == [("x3",), ("x1",), ("x4",)]


def test_keycache_byte_budget_keeps_fresh_entry(monkeypatch):
    """Under an impossibly small byte cap the freshly stored pack still
    survives (evicting the entry just built would defeat the replay), so
    the cache degenerates to exactly the most recent packing."""
    monkeypatch.setattr(J, "KEYCACHE_MAX_BYTES", 1)
    rng = np.random.default_rng(8)
    a = Relation({f"x{i}": rng.integers(0, 30, 150) for i in range(2)})
    b0 = Relation({"x0": rng.integers(0, 30, 60)})
    b1 = Relation({"x1": rng.integers(0, 30, 60)})
    J.join(a, b0)
    assert list(a._keycache) == [("x0",)]
    J.join(a, b1)
    assert list(a._keycache) == [("x1",)]       # fresh survives, LRU evicted
