"""Multi-tenant serving: admission loop stress, cross-query batching
primitives, and the kcap autotuner.

The load-bearing property is bit-identicality: θ pruning is sound at any
batching granularity, so interleaving N queries through the slot loop (with
pooled Phases 1-2 and cross-query fused Phase-3 launches) must reproduce
serial `StreakEngine.execute` results exactly — same scores, same rows.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import node_select
from repro.core.executor import ExecConfig, StreakEngine
from repro.core.planner import plan_query
from repro.core.spatial_join import (JoinStats, KcapTuner, StreamEntry,
                                     fused_stream_join,
                                     fused_stream_join_multi)
from repro.data.synth_rdf import make_lgd
from repro.serve.spatial import SpatialServeEngine


@pytest.fixture(scope="module")
def lgd():
    return make_lgd(n_per_class=150, seed=0, block=128)


@pytest.fixture(scope="module")
def mixed_queries(lgd):
    """8 tenants with mixed k (and thus mixed θ-termination profiles)."""
    ks = (5, 20, 60, 120)
    return [dataclasses.replace(q, k=ks[i % len(ks)])
            for i, q in enumerate(lgd.queries)]


def _serial(store, cfg, queries):
    out = []
    for q in queries:
        scores, rows, _ = StreakEngine(store, cfg).execute(q)
        out.append((scores, rows))
    return out


def _boxes(rng, n, size=0.03):
    lo = rng.random((n, 2))
    return np.concatenate([lo, lo + size * rng.random((n, 2))], axis=1)


# ------------------------------------------------- admission-loop stress ---
CONFIGS = [ExecConfig(),
           ExecConfig(join_backend="fused", fused_batch_cols=256),
           ExecConfig(join_backend="fused", fused_batch_cols=256,
                      kcap_auto=True)]


@pytest.mark.parametrize("cfg", CONFIGS, ids=["numpy", "fused", "fused-kcap"])
def test_serve_bit_identical_to_serial(lgd, mixed_queries, cfg):
    serial = _serial(lgd.store, cfg, mixed_queries)
    srv = SpatialServeEngine(lgd.store, cfg, max_slots=3)
    reqs = srv.serve(mixed_queries)
    assert [r.rid for r in reqs] == list(range(len(mixed_queries)))
    for req, (scores, rows) in zip(reqs, serial):
        assert req.done
        np.testing.assert_array_equal(req.scores, scores)
        assert req.rows.n == rows.n
        for v in req.query.select:
            if rows.n:      # an empty TopK relation carries no columns
                np.testing.assert_array_equal(req.rows[v.name], rows[v.name])
    # the slot loop really batched: pooled SIP calls covered several blocks
    assert srv.stats.sip_batches > 0
    assert srv.stats.sip_blocks > srv.stats.sip_batches
    if cfg.join_backend == "fused":
        assert srv.stats.join_launches > 0


def test_slot_reuse_and_no_starvation(lgd, mixed_queries):
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=2)
    reqs = srv.serve(mixed_queries)
    st = srv.stats
    assert all(r.done for r in reqs)
    assert st.admissions == len(mixed_queries)
    # 2 slots, 8 tenants: every admission past the first pair reuses a slot
    assert st.slot_reuse == len(mixed_queries) - 2
    assert st.max_queue >= 1
    # starvation check: every request became active and finished within the
    # global step budget; nobody queued forever
    for r in reqs:
        assert 1 <= r.steps <= st.steps
        assert r.waited < st.steps


def test_no_starvation_under_adversarial_long_short_mix(lgd):
    """Adversarial mix: two scan-heavy tenants (huge k never θ-terminates)
    submitted FIRST, six tiny-k tenants queued behind them on 2 slots. FIFO
    admission must keep the documented bound — every request runs and waits
    strictly less than the global step count — and admission order must
    follow submission order (waited non-decreasing), so the short queries
    are never starved by the long ones re-claiming slots."""
    long_q = [dataclasses.replace(lgd.queries[0], k=10 ** 6),
              dataclasses.replace(lgd.queries[1], k=10 ** 6)]
    short_q = [dataclasses.replace(q, k=3) for q in lgd.queries[2:]]
    serial = _serial(lgd.store, ExecConfig(), long_q + short_q)
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=2)
    reqs = srv.serve(long_q + short_q)
    st = srv.stats
    assert all(r.done and r.error is None for r in reqs)
    for r in reqs:
        assert 1 <= r.steps <= st.steps
        assert r.waited < st.steps          # the documented waited bound
    # FIFO: an earlier submission never waits longer than a later one
    waits = [r.waited for r in reqs]
    assert waits == sorted(waits)
    for req, (scores, _) in zip(reqs, serial):
        np.testing.assert_array_equal(req.scores, scores)


def test_share_cache_fifo_eviction_counts_and_stays_bounded(lgd, mixed_queries):
    """The >max memo bound evicts insertion-order (oldest per-block results
    first) instead of clearing wholesale, and counts what it dropped."""
    cfg = ExecConfig()
    serial = _serial(lgd.store, cfg, mixed_queries)
    srv = SpatialServeEngine(lgd.store, cfg, max_slots=3, share_cache_max=8)
    reqs = srv.serve(mixed_queries)
    assert srv.stats.share_evictions > 0
    assert len(srv.engine.share_cache) <= 8
    for req, (scores, _) in zip(reqs, serial):   # eviction never changes results
        np.testing.assert_array_equal(req.scores, scores)


def test_theta_termination_releases_slots_midflight(lgd, mixed_queries):
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=3)
    reqs = srv.serve(mixed_queries)
    # small-k tenants θ-terminate before exhausting their driver scan,
    # freeing slots for queued requests
    assert srv.stats.released_early >= 1
    early = [r for r in reqs if r.stats.early_terminated]
    assert early
    assert max(r.steps for r in early) < max(r.steps for r in reqs)


def test_serve_single_slot_degenerates_to_serial(lgd, mixed_queries):
    """max_slots=1 is plain serial execution through the serve loop."""
    serial = _serial(lgd.store, ExecConfig(), mixed_queries[:3])
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=1)
    reqs = srv.serve(mixed_queries[:3])
    for req, (scores, _) in zip(reqs, serial):
        np.testing.assert_array_equal(req.scores, scores)
    assert srv.stats.slot_reuse == 2


@pytest.mark.parametrize("cfg", CONFIGS, ids=["numpy", "fused", "fused-kcap"])
def test_hot_shape_tenants_share_work_bit_identical(lgd, cfg):
    """Tenants running the SAME query shape with per-tenant k hit the
    cross-tenant share cache (materialization, driven retrieval, MBR pairs,
    refine verdicts) and must stay bit-identical to serial — including the
    per-tenant scan-volume stats, which a cache hit replays rather than
    skips."""
    hot = [dataclasses.replace(lgd.queries[0], k=k) for k in (5, 20, 60, 120)]
    serial_stats = []
    serial = []
    for q in hot:
        scores, rows, st = StreakEngine(lgd.store, cfg).execute(q)
        serial.append((scores, rows))
        serial_stats.append(st)
    srv = SpatialServeEngine(lgd.store, cfg, max_slots=4)
    reqs = srv.serve(hot)
    for req, (scores, rows), st in zip(reqs, serial, serial_stats):
        np.testing.assert_array_equal(req.scores, scores)
        assert req.rows.n == rows.n
        assert req.stats.driven_rows_scanned == st.driven_rows_scanned
        assert req.stats.driven_rows_after_sip == st.driven_rows_after_sip
    assert srv.engine.share_cache  # sharing actually happened


# ------------------------------------------- cross-query join primitive ---
def test_multi_query_stream_join_matches_serial():
    rng = np.random.default_rng(1)
    entries, expected, got = [], [], []

    def canon(chunks):
        if not chunks:
            return np.empty((2, 0), np.int64)
        a = np.concatenate(chunks, axis=1)
        return a[:, np.lexsort((a[1], a[0]))]

    for qi in range(3):
        m, n = 40 + 8 * qi, 150 + 30 * qi
        drv, dvn = _boxes(rng, m), _boxes(rng, n)
        dk, vk = rng.random(m), rng.random(n)
        dist = 0.15 + 0.05 * qi
        expected.append(canon([np.stack([pi, pj]) for pi, pj in
                               fused_stream_join(drv, dvn, dk, vk, dist,
                                                 k=16)]))
        acc = []
        got.append(acc)
        entries.append(StreamEntry(
            drv, dvn, dk, vk, dist, 16, theta_fn=lambda: -np.inf,
            emit=lambda pi, pj, a=acc: a.append(np.stack([pi, pj]))))
    launches = fused_stream_join_multi(entries, batch_cols=128)
    assert launches >= 1
    for exp, acc in zip(expected, got):
        np.testing.assert_array_equal(canon(acc), exp)


def test_multi_query_stream_join_respects_per_query_theta():
    """A query whose θ already exceeds every pair bound emits nothing while
    its batch-mates still emit everything."""
    rng = np.random.default_rng(2)
    drv, dvn = _boxes(rng, 30), _boxes(rng, 100)
    dk, vk = rng.random(30), rng.random(100)
    open_acc, closed_acc = [], []
    entries = [
        StreamEntry(drv, dvn, dk, vk, 0.4, 8, theta_fn=lambda: -np.inf,
                    emit=lambda pi, pj: open_acc.append((pi, pj))),
        StreamEntry(drv, dvn, dk, vk, 0.4, 8, theta_fn=lambda: np.inf,
                    emit=lambda pi, pj: closed_acc.append((pi, pj))),
    ]
    fused_stream_join_multi(entries, batch_cols=64)
    assert open_acc and not closed_acc


# ------------------------------------------- pooled Phases 1-2 primitives ---
def test_multi_cs_candidate_nodes_matches_per_block(lgd):
    store = lgd.store
    plans = [plan_query(store, q) for q in lgd.queries[:3]]
    rng = np.random.default_rng(3)
    n_b = 6
    boxes = [_boxes(rng, 4, size=0.01)[: 2 + i % 3] for i in range(n_b)]
    cs_sets = [plans[i % 3].driven_cs for i in range(n_b)]
    dists = np.array([plans[i % 3].dist_norm for i in range(n_b)])
    in_v = store.tree.candidate_nodes(boxes, dists, cs_sets)
    assert in_v.shape == (n_b, store.tree.n_nodes)
    for i in range(n_b):
        ref = store.tree.candidate_nodes(boxes[i], float(dists[i]),
                                         cs_sets[i])
        np.testing.assert_array_equal(in_v[i], ref)


def test_select_batch_per_row_costs_match_per_block(lgd):
    store = lgd.store
    tree = store.tree
    plans = [plan_query(store, q) for q in lgd.queries[:2]]
    rng = np.random.default_rng(4)
    n_b = 4
    boxes = [_boxes(rng, 3, size=0.02) for _ in range(n_b)]
    cs_sets = [plans[i % 2].driven_cs for i in range(n_b)]
    dists = np.array([plans[i % 2].dist_norm for i in range(n_b)])
    in_v = tree.candidate_nodes(boxes, dists, cs_sets)
    card = np.stack([tree.cs_stats.cardinality_all(c) for c in cs_sets])
    sel = node_select.select_batch(tree, in_v, cs_sets, card_all=card)
    assert len(sel) == n_b
    for i in range(n_b):
        ref = node_select.select(tree, in_v[i], cs_sets[i])
        np.testing.assert_array_equal(sel[i], ref)


# ------------------------------------------------------- kcap autotuner ---
def test_kcap_tuner_ewma_math():
    t = KcapTuner(alpha=0.25, headroom=1.5, floor=8, ceiling=1024)
    assert t.ewma is None
    t.update(np.array([3, 10, 7]))      # folds the per-launch MAX
    assert t.ewma == 10.0
    t.update(np.array([20]))
    assert t.ewma == 0.25 * 20 + 0.75 * 10.0
    t.update(np.array([], dtype=np.int64))   # empty launch: no change
    assert t.ewma == 12.5


def test_kcap_tuner_suggest_clamps():
    t = KcapTuner()
    assert t.suggest(4, 4096) == 64      # cold start: legacy max(k, 64)
    assert t.suggest(100, 4096) == 128   # ... pow2-rounded above k
    t.ewma = 21.0                        # ceil(21 * 1.5) = 32 (exact pow2)
    assert t.suggest(4, 4096) == 32
    t.ewma = 22.0                        # ceil(33) -> next pow2 = 64
    assert t.suggest(4, 4096) == 64
    t.ewma = 1.0
    assert t.suggest(1, 4096) == 8       # floor
    assert t.suggest(100, 4096) == 128   # k dominates the floor
    t.ewma = 5000.0
    assert t.suggest(1, 4096) == 1024    # ceiling
    assert t.suggest(1, 16) == 16        # batch_cols caps everything


def test_kcap_undershoot_recovery_exact_and_recorded():
    """A tuner capped far below the survivor burst must not change the
    candidate set — overflowing rows are recovered densely — and the
    overflow must be visible in JoinStats."""
    rng = np.random.default_rng(5)
    drv, dvn = _boxes(rng, 48), _boxes(rng, 300)
    dk, vk = rng.random(48), rng.random(300)

    def run(stats=None, tuner=None):
        chunks = [np.stack([pi, pj]) for pi, pj in fused_stream_join(
            drv, dvn, dk, vk, 0.4, k=2, batch_cols=64,
            stats=stats, tuner=tuner)]
        a = np.concatenate(chunks, axis=1)
        return a[:, np.lexsort((a[1], a[0]))]

    base = run()
    stats = JoinStats()
    tight = KcapTuner(floor=1, ceiling=2)    # kcap pinned to 2 columns
    np.testing.assert_array_equal(run(stats=stats, tuner=tight), base)
    assert stats.overflow_rows > 0
    assert stats.overflow_batches > 0


def test_overflow_stats_recorded_without_tuner():
    """The fixed-width path records the (rare) silent overflow too."""
    rng = np.random.default_rng(6)
    drv, dvn = _boxes(rng, 30), _boxes(rng, 400)
    dk, vk = rng.random(30), rng.random(400)
    stats = JoinStats()
    # k=2 -> fixed kcap 64; dist 2.0 makes every pair survive (400 > 64)
    list(fused_stream_join(drv, dvn, dk, vk, 2.0, k=2, batch_cols=400,
                           stats=stats))
    assert stats.overflow_rows > 0
    assert stats.overflow_batches > 0


# ------------------------------------------------ kernel per-row state ---
def test_kernel_per_row_dist_theta_qid_matches_ref():
    """The serving-layer kernel form: per-row distance/θ planes + query-id
    masking, Pallas interpret vs the ref oracle."""
    from repro.kernels import ops as kops
    from repro.kernels.ref import fused_topk_join_ref
    rng = np.random.default_rng(7)
    m, n = 40, 130
    drv, dvn = (_boxes(rng, m).astype(np.float32),
                _boxes(rng, n).astype(np.float32))
    dk = rng.random(m).astype(np.float32)
    vk = rng.random(n).astype(np.float32)
    dist = (0.05 + 0.3 * rng.random(m)).astype(np.float32)
    theta = (0.6 * rng.random(m)).astype(np.float32)
    rq = rng.integers(0, 3, m).astype(np.int32)
    cq = rng.integers(0, 3, n).astype(np.int32)
    gs, gi, gc = kops.fused_topk_join(drv, dvn, dk, vk, dist, theta, k=16,
                                      row_qid=rq, col_qid=cq, interpret=True)
    ws, wi, wc = fused_topk_join_ref(drv, dvn, dk, vk, dist, theta, 16,
                                     row_qid=rq, col_qid=cq)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-6, atol=1e-6)
    # qid masking really bit: cross-query pairs never surface
    gi_np = np.asarray(gi)
    for r in range(m):
        cols = gi_np[r][gi_np[r] >= 0]
        assert (cq[cols] == rq[r]).all()
