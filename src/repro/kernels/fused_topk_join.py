"""Pallas TPU kernel: fused streaming top-k distance join (paper §3.3).

The matrix kernel (distance_join.py) materializes the full (M, N) distance
matrix in HBM and lets the caller mask it — throwing away the paper's core
insight that a top-k join only ever needs the pairs that can still beat the
shared threshold θ. This kernel fuses the whole Phase-3 predicate into the
tile loop: per (bm, bn) tile it

  1. computes MBR min-distances in VMEM,
  2. applies the distance predicate AND the score-key threshold
     (``driver_key[i] + driven_key[j] > θ`` — a sound upper bound on any
     result row produced by the pair, see core/spatial_join.py),
  3. folds each driver row's survivors into a running fixed-width per-row
     top-k partial (scores + driven indices) carried across the inner grid
     dimension,

so the only HBM outputs are (M, k) partials plus a per-row survivor count —
peak memory is independent of N. The count lets the caller detect rows whose
survivors overflowed the k-wide partial and recover them exactly (the
streaming wrapper densifies just those rows, keeping the join lossless).

The running-merge uses an iterative extract-max selection loop (max / where /
iota / dynamic_update_slice only) rather than lax.top_k, so the kernel stays
within Mosaic-supported primitives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _select_topk(cat_s: jnp.ndarray, cat_i: jnp.ndarray, k: int
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row top-k of (bm, W) scores with aligned indices.

    K-step extract-max: each step takes the row max, locates its first
    column (ties resolve to the lowest column, matching lax.top_k), records
    (score, index), and masks the column out. Mosaic-safe ops only.
    """
    bm, w = cat_s.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, w), 1)

    def body(t, carry):
        cur_s, out_s, out_i = carry
        m = jnp.max(cur_s, axis=1, keepdims=True)                  # (bm, 1)
        at_max = cur_s == m
        pick = jnp.min(jnp.where(at_max, iota, w), axis=1,
                       keepdims=True)                              # (bm, 1)
        sel = iota == pick                                         # one-hot
        idx = jnp.sum(jnp.where(sel, cat_i, 0), axis=1, keepdims=True)
        out_s = jax.lax.dynamic_update_slice(out_s, m, (0, t))
        out_i = jax.lax.dynamic_update_slice(out_i, idx, (0, t))
        cur_s = jnp.where(sel, NEG_INF, cur_s)
        return cur_s, out_s, out_i

    out_s = jnp.full((bm, k), NEG_INF, dtype=cat_s.dtype)
    out_i = jnp.full((bm, k), -1, dtype=jnp.int32)
    _, out_s, out_i = jax.lax.fori_loop(0, k, body, (cat_s, out_s, out_i))
    # padding steps re-pick masked (-inf) columns: scrub their stale indices
    out_i = jnp.where(out_s == NEG_INF, -1, out_i)
    return out_s, out_i


def _kernel(dist_ref, theta_ref, a_ref, ak_ref, aq_ref, b_ref, bk_ref,
            bq_ref, s_ref, i_ref, c_ref, *, bn: int, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, NEG_INF)
        i_ref[...] = jnp.full_like(i_ref, -1)
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[...]                                  # (bm, 4) driver boxes
    b = b_ref[...]                                  # (bn, 4) driven boxes
    ax0, ay0, ax1, ay1 = (a[:, 0:1], a[:, 1:2], a[:, 2:3], a[:, 3:4])
    bx0, by0, bx1, by1 = (b[:, 0].reshape(1, -1), b[:, 1].reshape(1, -1),
                          b[:, 2].reshape(1, -1), b[:, 3].reshape(1, -1))
    dx = jnp.maximum(0.0, jnp.maximum(ax0 - bx1, bx0 - ax1))
    dy = jnp.maximum(0.0, jnp.maximum(ay0 - by1, by0 - ay1))
    d = jnp.sqrt(dx * dx + dy * dy)                 # (bm, bn)

    bound = ak_ref[...] + bk_ref[...][:, 0].reshape(1, -1)   # (bm, bn)
    # per-ROW distance/theta (multi-query launches carry one per driver row)
    # and query-id masking: a pair only survives when driver and driven rows
    # belong to the same query
    same_q = aq_ref[...] == bq_ref[...][:, 0].reshape(1, -1)  # (bm, bn)
    valid = (d <= dist_ref[...]) & (bound > theta_ref[...]) & same_q
    col = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
           + j * bn)                                # global driven index
    tile_s = jnp.where(valid, bound, NEG_INF)
    tile_i = jnp.where(valid, col, -1)

    cat_s = jnp.concatenate([s_ref[...], tile_s], axis=1)    # (bm, k + bn)
    cat_i = jnp.concatenate([i_ref[...], tile_i], axis=1)
    top_s, top_i = _select_topk(cat_s, cat_i, k)
    s_ref[...] = top_s
    i_ref[...] = top_i
    c_ref[...] = c_ref[...] + jnp.sum(valid.astype(jnp.int32), axis=1,
                                      keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("k", "bm", "bn", "interpret"))
def fused_topk_join(driver: jnp.ndarray, driven: jnp.ndarray,
                    driver_keys: jnp.ndarray, driven_keys: jnp.ndarray,
                    dist, theta, k: int = 64,
                    bm: int = 128, bn: int = 128,
                    row_qid: jnp.ndarray | None = None,
                    col_qid: jnp.ndarray | None = None,
                    interpret: bool = False
                    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Streaming per-row top-k distance join.

    driver (M, 4) / driven (N, 4) MBRs; driver_keys (M,) / driven_keys (N,)
    per-entity score-key upper bounds (use 0 for a pure distance join, -inf
    to exclude an entity). `dist` and `theta` may be traced scalars — θ
    changes between tile batches without recompiling — or per-driver-row
    ``(M,)`` arrays, which is how a multi-query launch carries each query's
    own distance threshold and top-k state (serve/spatial.py). `row_qid` /
    `col_qid` are optional int32 query ids: when given, pairs whose driver
    row and driven column belong to different queries are masked out, so
    several queries' blocks share one kernel grid.

    Returns (scores (M, k) f32, idx (M, k) int32, counts (M,) int32): per
    driver row the k best surviving pairs by key bound (padded with
    -inf / -1) and the TOTAL survivor count (counts[i] > k ⟹ the partial
    overflowed and the caller must recover row i densely).
    """
    m, n = driver.shape[0], driven.shape[0]
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    drv = jnp.pad(driver.astype(jnp.float32), ((0, mp - m), (0, 0)))
    dvn = jnp.pad(driven.astype(jnp.float32), ((0, np_ - n), (0, 0)))
    # padded driven columns carry a -inf key: bound = -inf is never > θ
    # (θ ≥ -inf), so padding can never appear among the survivors
    dk = jnp.pad(driver_keys.astype(jnp.float32), (0, mp - m),
                 constant_values=NEG_INF).reshape(-1, 1)
    vk = jnp.pad(driven_keys.astype(jnp.float32), (0, np_ - n),
                 constant_values=NEG_INF).reshape(-1, 1)
    # scalar dist/theta broadcast to per-row columns; padded rows keep their
    # -inf key, so their dist/theta values are irrelevant
    dist_arr = jnp.pad(jnp.broadcast_to(
        jnp.asarray(dist, dtype=jnp.float32), (m,)), (0, mp - m)
    ).reshape(-1, 1)
    theta_arr = jnp.pad(jnp.broadcast_to(
        jnp.asarray(theta, dtype=jnp.float32), (m,)), (0, mp - m)
    ).reshape(-1, 1)
    # absent qids = everything is query 0; pads get -1 / -2 so a padded row
    # can never match a padded column either
    rq = (jnp.zeros(m, jnp.int32) if row_qid is None
          else row_qid.astype(jnp.int32))
    cq = (jnp.zeros(n, jnp.int32) if col_qid is None
          else col_qid.astype(jnp.int32))
    rq = jnp.pad(rq, (0, mp - m), constant_values=-1).reshape(-1, 1)
    cq = jnp.pad(cq, (0, np_ - n), constant_values=-2).reshape(-1, 1)
    grid = (mp // bm, np_ // bn)
    scores, idx, counts = pl.pallas_call(
        functools.partial(_kernel, bn=bn, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.float32),
            jax.ShapeDtypeStruct((mp, k), jnp.int32),
            jax.ShapeDtypeStruct((mp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(dist_arr, theta_arr, drv, dk, rq, dvn, vk, cq)
    return scores[:m], idx[:m], counts[:m, 0]
