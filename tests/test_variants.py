"""Perf-variant equivalence: the optimized paths must match the baselines."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.serve import retrieval


def _cfg(**kw):
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                d_ff=64, vocab=64, dtype="float32", remat=False)
    base.update(kw)
    return transformer.TransformerConfig(**base)


def test_scatter_cache_update_matches_onehot():
    cfg = _cfg()
    cfg_opt = dataclasses.replace(cfg, scatter_cache_update=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 64)
    c1 = transformer.init_cache(cfg, 2, 8)
    c2 = transformer.init_cache(cfg_opt, 2, 8)
    for t in range(6):
        l1, c1 = transformer.decode_step(params, c1, tokens[:, t],
                                         jnp.array([t, t]), cfg)
        l2, c2 = transformer.decode_step(params, c2, tokens[:, t],
                                         jnp.array([t, t]), cfg_opt)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]),
                               rtol=1e-5, atol=1e-6)


def test_bf16_operand_attention_close_to_f32():
    cfg = _cfg(dtype="bfloat16")
    cfg_opt = dataclasses.replace(cfg, attn_bf16_operands=True)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    h1 = transformer.forward(params, tokens, cfg)
    h2 = transformer.forward(params, tokens, cfg_opt)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_microbatch_accumulation_matches_full_batch_grads():
    cfg = _cfg(loss_chunks=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64)

    loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg)
    _, g_full = jax.value_and_grad(loss_fn)(params, tokens)

    def micro(gsum, tk):
        l, g = jax.value_and_grad(loss_fn)(params, tk)
        return jax.tree.map(lambda a, b: a + b, gsum, g), l
    zeros = jax.tree.map(jnp.zeros_like, params)
    gsum, _ = jax.lax.scan(micro, zeros, tokens.reshape(4, 2, 16))
    g_acc = jax.tree.map(lambda g: g / 4, gsum)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_streak_topk_sharded_matches_unsharded():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # plain make_mesh: jax.sharding.AxisType is absent in the pinned jax
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    state = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    items = jnp.asarray((rng.normal(size=(512, 8))
                         * rng.exponential(1.0, (512, 1))).astype(np.float32))
    block = 64
    items_s, order = retrieval.sort_items_by_norm(items, block)
    bounds = retrieval.block_bounds(items_s, block)
    s1, i1, _ = retrieval.streak_topk(state, items_s, order.astype(jnp.int32),
                                      bounds, k=8, block=block)
    with mesh:
        s2, i2, _ = retrieval.streak_topk_sharded(
            state, items_s, order.astype(jnp.int32), bounds, mesh=mesh,
            axis="model", k=8, block=block)
    np.testing.assert_allclose(np.sort(np.asarray(s1), axis=-1),
                               np.sort(np.asarray(s2), axis=-1), rtol=1e-5)
