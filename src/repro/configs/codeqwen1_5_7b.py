"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B; hf]: 32L d_model=4096 32H
(GQA kv=32 = MHA) d_ff=13440 vocab=92416, SwiGLU, RMSNorm."""
from ..models.transformer import TransformerConfig
from .registry import LM_SHAPES as SHAPES  # noqa: F401

FAMILY = "lm"
CONFIG = TransformerConfig(
    name="codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, head_dim=128, d_ff=13440, vocab=92416,
    act="silu", glu=True, norm="rms", rope_theta=1e6,
    dtype="bfloat16", remat=True, loss_chunks=16)
SMOKE = TransformerConfig(
    name="codeqwen1.5-7b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=320, vocab=512,
    act="silu", glu=True, norm="rms", dtype="float32", remat=False)
