"""Pallas TPU kernel: the rank pass of the two-phase sort-merge join.

The relational path (paper §3.2.1-3.2.2) joins pattern scans over the
sorted permutation indexes. core/join.py reduces every equi-join to one
primitive over *scalar composite keys*: given a sorted int64 table and a
batch of int64 probes, find each probe's lower and upper insertion rank

    lo[i] = |{ j : table[j] <  probe[i] }|
    hi[i] = |{ j : table[j] <= probe[i] }|

(`hi - lo` is the match multiplicity; the gather pass then materializes the
matching pairs with CSR cumsum/repeat arithmetic).

The engine runs without jax x64, so the wrapper (kernels/ops.py) splits the
int64 keys into (hi32, biased lo32) int32 planes on the host — comparing
(signed hi, signed lo-with-flipped-sign-bit) lexicographically equals the
int64 comparison, the same trick bloom_probe uses for its key halves — and
everything below is pure 32-bit math.

TPU has no efficient per-lane gather, so instead of a binary search the
kernel uses the VPU-friendly *counting* form: each (bb,)-probe block
broadcasts against the whole table resident in VMEM and sums the two
comparison masks over the lane axis. The table is padded with int64-max
sentinel planes, which compare strictly greater than any real probe
(core/join.py packs keys into [0, 2^63-1)), so padding never counts. Work
is O(M·N) compares versus O(M·log N) for the binary search, but it is all
8x128 VPU compares with zero control flow.

The table axis is tiled INSIDE the kernel: the table planes stay in HBM
(`memory_space=ANY`) and stream through a two-slot VMEM scratch with
explicit async copies — tile j+1's DMA is issued before tile j's compare
pass runs, so for tables past VMEM the HBM stream overlaps the VPU
counting loop instead of serializing with it (double buffering). Each grid
step is one probe block; its rank pair accumulates in registers across the
tile loop. Tables that fit a single tile degenerate to one warm-up copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# planes of the int64-max padding sentinel: hi = 0x7FFFFFFF and
# lo = 0xFFFFFFFF ^ sign-bit-flip = 0x7FFFFFFF
_SENT = 0x7FFFFFFF


def _plane_lt_le(t_hi, t_lo, p_hi, p_lo):
    """Broadcasted (table < probe, table <= probe) on split int64 planes."""
    hi_eq = t_hi == p_hi
    lt = (t_hi < p_hi) | (hi_eq & (t_lo < p_lo))
    le = lt | (hi_eq & (t_lo == p_lo))
    return lt, le


def _kernel(n_tiles: int, tn: int,
            t_ref, p_hi_ref, p_lo_ref, lo_ref, hi_ref):
    """One probe block against the whole table.

    `t_ref` is the stacked (2, n_pad) hi/lo plane array left in HBM; tiles
    stream through a (2 slots, 2 planes, tn) VMEM scratch. The next tile's
    copy is started BEFORE waiting on the current one, so tile j+1's HBM
    read overlaps tile j's O(bb·tn) compare-and-sum.
    """
    p_hi = p_hi_ref[...]                                   # (bb, 1)
    p_lo = p_lo_ref[...]

    def scoped(scratch, sem):
        def copy_in(slot, j):
            return pltpu.make_async_copy(
                t_ref.at[:, pl.ds(j * tn, tn)], scratch.at[slot],
                sem.at[slot])

        copy_in(0, 0).start()                              # warm-up

        def body(j, carry):
            lo_acc, hi_acc = carry
            slot = jax.lax.rem(j, 2)

            @pl.when(j + 1 < n_tiles)
            def _prefetch():
                copy_in(jax.lax.rem(j + 1, 2), j + 1).start()

            copy_in(slot, j).wait()
            blk = scratch[slot]                            # (2, tn)
            lt, le = _plane_lt_le(blk[0:1, :], blk[1:2, :], p_hi, p_lo)
            return (lo_acc + jnp.sum(lt.astype(jnp.int32), axis=1,
                                     keepdims=True),
                    hi_acc + jnp.sum(le.astype(jnp.int32), axis=1,
                                     keepdims=True))

        z = jnp.zeros(lo_ref.shape, jnp.int32)
        lo, hi = jax.lax.fori_loop(0, n_tiles, body, (z, z))
        lo_ref[...] = lo
        hi_ref[...] = hi

    pl.run_scoped(scoped,
                  scratch=pltpu.VMEM((2, 2, tn), jnp.int32),
                  sem=pltpu.SemaphoreType.DMA((2,)))


@functools.partial(jax.jit, static_argnames=("bb", "tn", "interpret"))
def merge_join_ranks(t_hi: jnp.ndarray, t_lo: jnp.ndarray,
                     p_hi: jnp.ndarray, p_lo: jnp.ndarray,
                     bb: int = 1024, tn: int = 8192,
                     interpret: bool = False
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Counting rank pass over one probe batch.

    t_* (N,) / p_* (M,) int32 planes of sorted table keys / probe keys
    (see `ops.split_key_planes`; table sorted by the underlying int64).
    `tn` bounds the VMEM-resident table tile (lane-rounded, clamped to the
    padded table size so small tables stay single-tile).
    Returns (lo (M,), hi (M,)) int32 insertion ranks.
    """
    m = p_hi.shape[0]
    n = t_hi.shape[0]
    tn = max(-(-tn // 128) * 128, 128)
    n128 = max(-(-n // 128) * 128, 128)
    tn = min(tn, n128)
    n_pad = -(-n128 // tn) * tn
    mp = max(-(-m // bb) * bb, bb)
    t_hi = jnp.pad(t_hi, (0, n_pad - n), constant_values=_SENT)
    t_lo = jnp.pad(t_lo, (0, n_pad - n), constant_values=_SENT)
    p_hi = jnp.pad(p_hi, (0, mp - m))
    p_lo = jnp.pad(p_lo, (0, mp - m))
    t_planes = jnp.stack([t_hi, t_lo])                     # (2, n_pad)
    lo, hi = pl.pallas_call(
        functools.partial(_kernel, n_pad // tn, tn),
        grid=(mp // bb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),          # table: HBM
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bb, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bb, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32)],
        interpret=interpret,
    )(t_planes, p_hi.reshape(-1, 1), p_lo.reshape(-1, 1))
    return lo[:m, 0], hi[:m, 0]


@functools.partial(jax.jit, static_argnames=("side",))
def merge_join_ranks_host(t_hi: jnp.ndarray, t_lo: jnp.ndarray,
                          p_hi: jnp.ndarray, p_lo: jnp.ndarray,
                          side: str = "both"):
    """CPU twin: branchless binary search, vectorized over probes — the
    loop-structured O(M·log N) form of the kernel's counting semantics
    (integer-exact, so all routes are bit-identical). log2(N) unrolled
    steps, each two gathers + one plane compare over the probe vector.
    side="left"/"right" skips the unused bound's search entirely."""
    n = t_hi.shape[0]
    if n == 0:
        z = jnp.zeros(p_hi.shape, dtype=jnp.int32)
        return (z, z) if side == "both" else z

    def bound(strict: bool) -> jnp.ndarray:
        pos = jnp.zeros(p_hi.shape, dtype=jnp.int32)
        step = 1 << max(int(n).bit_length(), 1)
        while step:
            # can we extend the all-pred prefix to pos + step?
            idx = jnp.minimum(pos + (step - 1), n - 1)
            lt, le = _plane_lt_le(jnp.take(t_hi, idx), jnp.take(t_lo, idx),
                                  p_hi, p_lo)
            pred = lt if strict else le
            pos = jnp.where((pos + step <= n) & pred, pos + step, pos)
            step >>= 1
        return pos

    if side == "left":
        return bound(True)
    if side == "right":
        return bound(False)
    return bound(True), bound(False)
