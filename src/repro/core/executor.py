"""STREAK block-wise query execution (paper Figure 5).

Driver bindings are retrieved in score-key order (blocks), each block is
SIP-filtered against the S-QuadTree (Phases 1+2), routed through the APS
decision (N-Plan vs S-Plan) for driven retrieval, spatially joined (Phase 3),
refined, scored, and pushed into the shared top-k state. Early termination
fires when the best possible remaining score key cannot beat theta.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import numpy as np

from . import aps, node_select, shard as shard_mod, spatial_join
from .join import Relation, filter_in_ranges, join, scan_pattern
from .planner import QueryPlan, SidePlan, plan_query
from .policy import BackendPolicy
from .query import Query, Var
from .spatial_join import JoinStats
from .store import DirectedNumericScan, QuadStore
from .topk import TopK


@dataclasses.dataclass
class ExecConfig:
    """Engine configuration.

    Backend selection lives on ``policy`` (core/policy.BackendPolicy), one
    frozen value resolved once in ``__post_init__`` — every per-stage knob
    below it (``join_backend`` .. ``kcap_auto``) is a deprecated shim that
    folds into the policy with a DeprecationWarning and then carries the
    RESOLVED backend back out, so legacy readers observe the same strings
    the engine executes with.
    """
    block: int = 1024
    use_sip: bool = True
    force_plan: str | None = None       # "N" | "S" | None (adaptive)
    force_driver: str | None = None     # "a" | "b" | None
    # deprecated per-stage shims -> policy.join / .impl (see __post_init__)
    join_backend: str | None = None
    join_impl: str | None = None
    fused_batch_cols: int = 4096        # driven columns per fused-kernel call
    refine_chunk: int = 1024            # candidate pairs refined per θ check
    sip_lookahead: int = 8              # driver blocks per batched SIP call
    probe_backend: str | None = None    # deprecated shim -> policy.probe
    rank_backend: str | None = None     # deprecated shim -> policy.rank
    kcap_auto: bool | None = None       # deprecated shim -> policy.kcap
    mbr_join_fn: Callable | None = None  # override Phase-3 MBR join (baselines)
    select_params: node_select.SelectParams = dataclasses.field(
        default_factory=node_select.SelectParams)
    cost_params: aps.CostParams = dataclasses.field(
        default_factory=aps.CostParams)
    policy: BackendPolicy | None = None  # backend selection; None = all-auto

    def __post_init__(self) -> None:
        legacy = {"join": self.join_backend, "impl": self.join_impl,
                  "probe": self.probe_backend, "rank": self.rank_backend,
                  "kcap": (None if self.kcap_auto is None
                           else ("auto" if self.kcap_auto else "fixed"))}
        legacy = {k: v for k, v in legacy.items() if v is not None}
        base = self.policy if self.policy is not None else BackendPolicy()
        if legacy:
            names = {"join": "join_backend", "impl": "join_impl",
                     "probe": "probe_backend", "rank": "rank_backend",
                     "kcap": "kcap_auto"}
            warnings.warn(
                "ExecConfig per-stage backend knobs ("
                + ", ".join(names[k] for k in legacy)
                + ") are deprecated; use ExecConfig(policy=BackendPolicy("
                + ", ".join(f"{k}={v!r}" for k, v in legacy.items()) + "))",
                DeprecationWarning, stacklevel=3)
            base = dataclasses.replace(base, **legacy)
        self.policy = base.resolve()
        # resolved write-back: legacy readers keep seeing concrete backends
        self.join_backend = self.policy.join
        self.join_impl = self.policy.impl
        self.probe_backend = self.policy.probe
        self.rank_backend = self.policy.rank
        self.kcap_auto = self.policy.kcap == "auto"


@dataclasses.dataclass
class ExecStats:
    driver_blocks: int = 0
    plan_n: int = 0
    plan_s: int = 0
    driven_rows_scanned: int = 0
    driven_rows_after_sip: int = 0
    results_considered: int = 0
    early_terminated: bool = False
    # anytime-results contract (core/fault.QueryDeadline): `partial` marks a
    # deadline-truncated answer; `score_bound` is the certified key-space
    # bound — no result outside the returned set has a key above it (for a
    # complete run it is simply the final θ)
    partial: bool = False
    deadline_expired: bool = False
    score_bound: float | None = None
    v_star_sizes: list = dataclasses.field(default_factory=list)
    join: JoinStats = dataclasses.field(default_factory=JoinStats)
    plan_log: list = dataclasses.field(default_factory=list)


class StreakEngine:
    def __init__(self, store: QuadStore, config: ExecConfig | None = None):
        self.store = store
        self.config = config or ExecConfig()
        self._scan_cache: dict = {}
        # one tuner per engine: survivor statistics carry across queries,
        # which is exactly the serving workload the autotuner targets
        self.kcap_tuner = (spatial_join.KcapTuner()
                           if self.config.policy.kcap == "auto" else None)
        # cross-tenant work sharing (serve mode): the serving layer sets
        # this to a dict, and per-block sub-results that are PURE functions
        # of (side signature, block) or (side signature, SIP intervals) —
        # driver-block materialization, S-Plan filtered retrieval, N-Plan
        # per-block joins — are memoized so concurrent tenants running the
        # same query shape (e.g. different k) compute them once.
        # θ-dependent work (guards, APS key_needed, N-Plan truncation,
        # TopK) stays per-tenant, so shared results are bit-identical.
        self.share_cache: dict | None = None

    @staticmethod
    def _side_sig(side: SidePlan, plan: QueryPlan) -> tuple:
        """Hashable identity of everything a side's block materialization /
        driven retrieval depends on (patterns fix the primary scan; the
        ranking direction fixes its block order)."""
        return (tuple((tp.g, tp.s, tp.p, tp.o) for tp in side.all_ordered),
                side.entity_var, plan.descending, plan.join_impl,
                plan.rank_backend)

    # ------------------------------------------------------------------
    def _cached_scan(self, tp) -> Relation:
        key = (tp.g, tp.s, tp.p, tp.o)
        if key not in self._scan_cache:
            self._scan_cache[key] = scan_pattern(self.store, tp)
        return self._scan_cache[key]

    def _join_chain(self, base: Relation, patterns: list,
                    impl: str | None = None,
                    backend: str | None = None) -> Relation:
        rel = base
        for tp in patterns:
            if rel.n == 0:
                # empty stays empty, but the schema must stay complete —
                # downstream consumers (and the brute-force oracles) expect
                # every pattern's variables as (empty) columns
                scan = self._cached_scan(tp)
                cols = {c: rel[c] for c in rel.keys()}
                for c in scan.keys():
                    if c not in cols:
                        cols[c] = np.empty(0, dtype=np.int64)
                rel = Relation(cols)
                continue
            rel = join(rel, self._cached_scan(tp), impl=impl, backend=backend)
        return rel

    def _block_relation(self, side: SidePlan, b: int) -> tuple[Relation, np.ndarray]:
        """Relation for one primary-scan block + its score-key values."""
        vals, subj, obj, facts = side.scan.get_block(b)
        tp = side.primary[0]
        rel = Relation()
        if isinstance(tp.s, Var):
            rel[tp.s.name] = subj
        if isinstance(tp.o, Var):
            rel[tp.o.name] = obj
        if isinstance(tp.g, Var):
            rel[tp.g.name] = facts
        return rel, vals

    # score-key weight of a term: flips sign for ascending ranking
    @staticmethod
    def _kw(weight: float, descending: bool) -> float:
        return weight if descending else -weight

    def _side_bound(self, side: SidePlan, descending: bool,
                    exclude_primary: bool) -> float:
        """Best possible score-key contribution from this side's quant terms."""
        total = 0.0
        for tp, var, w in side.quant_terms:
            if exclude_primary and side.primary is not None and tp is side.primary[0]:
                continue
            scan = DirectedNumericScan(self.store.numeric[int(tp.p)], descending)
            kw = self._kw(w, descending)
            v_best = scan.ni.block_max[0] if kw > 0 else scan.ni.block_min[-1]
            total += kw * float(v_best)
        return total

    def _score_key(self, rel: Relation, plan: QueryPlan) -> np.ndarray:
        """Score key per row = sum_i kw_i * value(?v_i)."""
        out = np.zeros(rel.n)
        for side in (plan.driver, plan.driven):
            for tp, var, w in side.quant_terms:
                kw = self._kw(w, plan.descending)
                out += kw * self.store.values_of(rel[var])
        return out

    def _entity_key_bound(self, rel: Relation, ents: np.ndarray,
                          side: SidePlan, plan: QueryPlan) -> np.ndarray:
        """Per-entity upper bound on this side's score-key contribution.

        Any result row pairing entities (e_i, e_j) joins one `rel` row per
        side, so max-over-rows per entity bounds the pair's score key from
        above — the soundness condition for the fused kernel's θ pruning.
        Rows whose contribution is NaN (entity lacks a value) can never
        score and count as -inf; an entity with only such rows gets -inf.
        """
        contrib = np.zeros(rel.n)
        for tp, var, w in side.quant_terms:
            kw = self._kw(w, plan.descending)
            contrib += kw * self.store.values_of(rel[var])
        contrib = np.where(np.isnan(contrib), -np.inf, contrib)
        out = np.full(len(ents), -np.inf)
        ent_col = rel[side.entity_var]
        pos = np.searchsorted(ents, ent_col)        # ents is sorted unique
        ok = (pos < len(ents)) & \
            (ents[np.minimum(pos, len(ents) - 1)] == ent_col)
        np.maximum.at(out, pos[ok], contrib[ok])
        return out

    def _emit_pairs(self, pi: np.ndarray, pj: np.ndarray,
                    uniq_ents: np.ndarray, dvn_ents: np.ndarray,
                    drv_rel: Relation, dvn_rel: Relation,
                    driver: SidePlan, driven: SidePlan, plan: QueryPlan,
                    topk: TopK, stats: ExecStats,
                    ds: np.ndarray | None = None,
                    vs: np.ndarray | None = None) -> None:
        """θ-aware refinement: order pairs by key bound, refine in chunks.

        Candidate pairs are sorted by descending score-key bound
        ``ds[i] + vs[j]`` (an upper bound on any result row the pair can
        produce, see `_entity_key_bound`), refined chunk-wise against the
        exact geometry pool, and survivors are scored and pushed into the
        top-k *between* chunks — so once the best remaining bound cannot
        beat θ, the whole tail of candidate pairs is skipped without ever
        touching its geometry (the paper's early termination applied to the
        refinement stage itself).
        """
        if len(pi) == 0:
            return
        store = self.store
        if ds is None:
            ds = self._entity_key_bound(drv_rel, uniq_ents, driver, plan)
        if vs is None:
            vs = self._entity_key_bound(dvn_rel, dvn_ents, driven, plan)
        bounds = ds[pi] + vs[pj]
        order = np.argsort(-bounds, kind="stable")
        pi, pj, bounds = pi[order], pj[order], bounds[order]
        # resolve pool rows once per unique entity, gather per pair
        rows_a = store.geom_rows(uniq_ents)[pi]
        rows_b = store.geom_rows(dvn_ents)[pj]
        chunk = max(int(self.config.refine_chunk), 1)
        for start in range(0, len(pi), chunk):
            # bounds are sorted: bounds[start] caps every remaining pair
            if topk.full and bounds[start] <= topk.theta:
                stats.join.refine_skipped += len(pi) - start
                break
            end = min(start + chunk, len(pi))
            # exact-geometry chunk verdicts are pure in (pool rows,
            # distance, metric); same-shape tenants chunk identically
            # (same pairs, same bound order), so serve mode shares them
            sc = self.share_cache
            rkey = None
            if sc is not None:
                rkey = ("refine", plan.metric, float(plan.dist_world),
                        rows_a[start:end].tobytes(),
                        rows_b[start:end].tobytes())
            if rkey is not None and rkey in sc:
                keep = sc[rkey]
            else:
                keep = spatial_join.refine(
                    pi[start:end], pj[start:end], store.geom_pool,
                    rows_a[start:end], rows_b[start:end],
                    plan.dist_world, plan.metric, stats.join)
                if rkey is not None:
                    sc[rkey] = keep
            ci, cj = pi[start:end][keep], pj[start:end][keep]
            if len(ci) == 0:
                continue
            pair_rel = Relation({driver.entity_var: uniq_ents[ci],
                                 driven.entity_var: dvn_ents[cj]})
            out = join(drv_rel, pair_rel, impl=plan.join_impl,
                       backend=plan.rank_backend)
            out = join(out, dvn_rel, impl=plan.join_impl,
                       backend=plan.rank_backend)
            if out.n == 0:
                continue
            keys = self._score_key(out, plan)
            valid = ~np.isnan(keys)
            out, keys = out.take(np.flatnonzero(valid)), keys[valid]
            stats.results_considered += out.n
            topk.push(keys, out)

    # ------------------------------------------------------------------
    def execute(self, q: Query, deadline=None
                ) -> tuple[np.ndarray, Relation, ExecStats]:
        cur = self.cursor(q, deadline=deadline)
        while not cur.done:
            cur.step()
        return cur.results()

    def cursor(self, q: Query, deadline=None):
        """Steppable execution state (one driver block per step) for the
        multi-tenant serving loop (serve/spatial.py). Non-top-k shapes
        (range / within / kNN / spatial join, core/shapes.py) return a
        `ShapeCursor` speaking the same protocol."""
        if q.spatial is not None and q.shape() != "topk":
            from .shapes import ShapeCursor
            return ShapeCursor(self, q, deadline=deadline)
        return QueryCursor(self, q, deadline=deadline)

    # ------------------------------------------------------------------
    def _driven_full(self, driven: SidePlan, impl: str | None,
                     backend: str | None = None) -> Relation:
        """Fully-joined driven sub-query, cached per query (S-Plan is a
        full scan per the paper; only the SIP filter varies per block)."""
        # key on the pattern *contents*: id(tp) can collide after pattern
        # objects are garbage-collected, silently reusing a stale relation
        key = ("__driven_full", impl, backend) \
            + tuple((tp.g, tp.s, tp.p, tp.o) for tp in driven.all_ordered)
        if key not in self._scan_cache:
            rel = self._cached_scan(driven.all_ordered[0])
            rel = self._join_chain(rel, driven.all_ordered[1:], impl, backend)
            self._scan_cache[key] = rel
        return self._scan_cache[key]

    def _driven_splan(self, driven: SidePlan, plan: QueryPlan, intervals,
                      explicit, stats: ExecStats) -> Relation:
        """S-Plan: spatial join pushed down -- one full scan of the driven
        sub-query (cached), then I-Range/E-list skipping of its rows."""
        rel = self._driven_full(driven, plan.join_impl, plan.rank_backend)
        stats.driven_rows_scanned += rel.n
        if self.config.use_sip and driven.entity_var in rel:
            sc, key = self.share_cache, None
            if sc is not None:
                key = ("splan", self._side_sig(driven, plan),
                       intervals.tobytes(), explicit.tobytes())
            if key is not None and key in sc:
                rel = sc[key]
            else:
                rel = filter_in_ranges(rel, driven.entity_var, intervals,
                                       explicit, impl=plan.join_impl,
                                       backend=plan.rank_backend)
                if key is not None:
                    sc[key] = rel
        stats.driven_rows_after_sip += rel.n
        return rel

    def _driven_nplan(self, driven: SidePlan, plan: QueryPlan, intervals,
                      explicit, key_needed: float, stats: ExecStats) -> Relation:
        """N-Plan: numeric predicate pushed down -- block-wise driven scan in
        score-key order with SIP skipping and threshold early termination."""
        cfg = self.config
        parts: list[Relation] = []
        kw = self._kw(driven.primary[2], plan.descending)
        sc = self.share_cache
        sig = self._side_sig(driven, plan) if sc is not None else None
        for b2 in range(driven.scan.n_blocks):
            best = kw * float(driven.scan.get_block(b2)[0][0])
            if np.isfinite(key_needed) and best <= key_needed:
                break  # no further driven block can reach the threshold
            # the per-block retrieval is θ-independent (only the truncation
            # above is), so concurrent same-shape tenants share it
            key = None
            if sc is not None:
                key = ("nblk", sig, b2, intervals.tobytes(),
                       explicit.tobytes())
            if key is not None and key in sc:
                scanned, joined = sc[key]
                stats.driven_rows_scanned += scanned
            else:
                block_rel, _ = self._block_relation(driven, b2)
                scanned = block_rel.n
                stats.driven_rows_scanned += scanned
                if cfg.use_sip and driven.entity_var in block_rel:
                    block_rel = filter_in_ranges(block_rel,
                                                 driven.entity_var,
                                                 intervals, explicit,
                                                 impl=plan.join_impl,
                                                 backend=plan.rank_backend)
                joined = self._join_chain(block_rel, driven.join_patterns,
                                          plan.join_impl, plan.rank_backend)
                if cfg.use_sip and driven.entity_var not in block_rel \
                        and driven.entity_var in joined:
                    joined = filter_in_ranges(joined, driven.entity_var,
                                              intervals, explicit,
                                              impl=plan.join_impl,
                                              backend=plan.rank_backend)
                if key is not None:
                    sc[key] = (scanned, joined)
            stats.driven_rows_after_sip += joined.n
            if joined.n:
                parts.append(joined)
        if not parts:
            return Relation()
        cols = parts[0].keys()
        return Relation({c: np.concatenate([p[c] for p in parts]) for c in cols})


class QueryCursor:
    """Steppable execution state of one query: one driver block per step.

    ``execute()`` is literally ``while not done: step()`` — block order, the
    per-block θ checks, and the `sip_lookahead` prefetch window are unchanged
    from the monolithic loop, so serial results are bit-identical to the
    pre-cursor engine.

    The serving layer (serve/spatial.py) instead drives the two-phase form:
    ``begin_block()`` runs the early-termination check, materializes the next
    driver block, and returns the Phase-1/2 *request* (driver boxes + CS
    material) so the server can batch candidate-node search and node
    selection ACROSS queries; ``finish_block(v_star, batcher)`` then runs
    APS + driven retrieval + the Phase-3 join, optionally registering the
    fused join with a cross-query batcher instead of streaming it alone.
    θ pruning is sound at every granularity, so results do not depend on how
    blocks from different queries interleave.
    """

    def __init__(self, engine: StreakEngine, q: Query, deadline=None):
        self.engine = engine
        self.deadline = deadline            # core/fault.QueryDeadline | None
        cfg = engine.config
        store = engine.store
        self.tree = store.tree
        self.plan = plan_query(store, q, force_driver=cfg.force_driver,
                               policy=cfg.policy)
        self.stats = ExecStats()
        self.topk = TopK(k=self.plan.k, descending=True)  # key space
        self.driver, self.driven = self.plan.driver, self.plan.driven
        self.driver_other = engine._side_bound(
            self.driver, self.plan.descending, exclude_primary=True)
        self.driven_bound = engine._side_bound(
            self.driven, self.plan.descending, exclude_primary=False)
        self.kw_p = (engine._kw(self.driver.primary[2], self.plan.descending)
                     if self.driver.primary else 0.0)
        # Morton-prefix shard views: one no-clip view on an unsharded
        # store (the literal old code path), the store's shard list on a
        # ShardedQuadStore. SIP disabled ⟹ no interval filtering, so the
        # per-shard loop would replicate the driven side — collapse to the
        # single global view instead.
        self.shards = (shard_mod.shard_views(store) if cfg.use_sip
                       else shard_mod.whole_view(store)) \
            if store.tree is not None else []
        # per-query (block-invariant) driven-CS cardinality per shard node
        self.card_all = [sh.tree.cs_stats.cardinality_all(self.plan.driven_cs)
                         for sh in self.shards]
        # query-invariant probe material: driven-CS keys hashed once and
        # reused by every frontier level of every window; `prepare` is pure
        # in (keys, bloom geometry) and the shard builder copies the global
        # Bloom geometry, so ONE prepared serves every shard
        self.prepared = (self.tree.bloom_self.prepare(self.plan.driven_cs)
                         if cfg.use_sip else None)
        # fused-descent routes probe the Bloom root paths ONCE per query
        # (block/box-independent, see SQuadTree.cs_path_mask) instead of
        # once per frontier level of every lookahead window — per shard
        self.cs_path = (
            [sh.tree.cs_path_mask(self.plan.driven_cs,
                                  prepared=self.prepared,
                                  probe_backend=self.plan.probe_backend)
             for sh in self.shards]
            if cfg.use_sip and self.plan.descend_backend != "numpy" else None)
        self.window = max(int(cfg.sip_lookahead), 1) if cfg.use_sip else 1
        self._drv_sig = engine._side_sig(self.driver, self.plan)
        self.pending: dict[int, tuple] = {}  # block -> (rel, ents, boxes)
        self._vstars: dict[int, np.ndarray] = {}   # block -> prefetched V*
        self._win_blocks: list[int] = []     # rows of an open SIP request
        self.n_blocks = (self.driver.scan.n_blocks
                         if self.driver.scan is not None else 1)
        self.b = 0
        self.done = False
        self._cur: tuple | None = None      # begin_block() materialization
        if self.n_blocks == 0:
            self._finish()

    # -- lifecycle ------------------------------------------------------
    def _finish(self) -> None:
        self.done = True

    def results(self) -> tuple[np.ndarray, Relation, ExecStats]:
        """Scores/rows of the TopK plus stats. Always safe to call: on a
        deadline-truncated cursor (``stats.partial``) the returned set is
        the anytime answer and ``stats.score_bound`` certifies it — no
        result outside the set has a key above the bound."""
        keys, rows = self.topk.results()
        scores = keys if self.plan.descending else -keys
        if self.stats.score_bound is None and self.done:
            # complete run: every candidate was seen, θ is the exact bound
            self.stats.score_bound = float(self.topk.theta)
        return scores, rows, self.stats

    # -- shared per-block pieces ----------------------------------------
    def _block_guard(self, b: int) -> bool:
        """Early-termination + deadline check; False ⟹ query finished."""
        if self.driver.scan is not None:
            dpb = self.kw_p * float(self.driver.scan.get_block(b)[0][0])
        else:  # no numeric driver: no driver bound
            dpb = 0.0
        self._driver_primary_best = dpb
        ub = dpb + self.driver_other + self.driven_bound
        if self.topk.full and ub <= self.topk.theta:
            self.stats.early_terminated = True
            self._finish()
            return False
        if self.deadline is not None \
                and self.deadline.expired(self.stats.driver_blocks):
            # stop admitting driver blocks: the current TopK is the anytime
            # answer. Unseen pairs (block >= b) are bounded by ub (blocks
            # arrive in score-key order, so ub is non-increasing); pairs
            # seen but dropped from the heap are bounded by θ — the max
            # certifies every unreturned result (θ is -inf until the heap
            # fills, in which case nothing was dropped and ub alone binds).
            self.stats.deadline_expired = True
            self.stats.partial = True
            self.stats.score_bound = max(float(self.topk.theta), ub)
            self._finish()
            return False
        return True

    def _materialize(self, w: int) -> tuple:
        """(drv_rel, uniq_ents, boxes) for driver block `w`."""
        eng, plan, driver = self.engine, self.plan, self.driver
        sc = eng.share_cache
        key = ("mat", self._drv_sig, w) if sc is not None else None
        if key is not None and key in sc:
            return sc[key]
        if driver.scan is not None:
            block_rel, _ = eng._block_relation(driver, w)
            join_chain = driver.join_patterns
        else:  # no numeric driver: single full block
            block_rel = eng._cached_scan(driver.all_ordered[0])
            join_chain = driver.all_ordered[1:]
        drv_rel = eng._join_chain(block_rel, join_chain, plan.join_impl,
                                  plan.rank_backend)
        uniq_ents = boxes = None
        if drv_rel.n:
            # driver entities with geometry
            uniq_ents = np.unique(drv_rel[driver.entity_var])
            boxes = eng.store.spatial_box_of(uniq_ents)
            has_geom = ~np.isnan(boxes[:, 0])
            uniq_ents, boxes = uniq_ents[has_geom], boxes[has_geom]
        if key is not None:
            sc[key] = (drv_rel, uniq_ents, boxes)
        return drv_rel, uniq_ents, boxes

    def _sip_prefetch(self, b0: int) -> None:
        """Phases 1-2 for a `sip_lookahead` window of driver blocks: one
        batched candidate-node search + node selection, shared Bloom-row
        gathers and MBR tests across blocks (per shard). Speculative work
        past an early termination cut is discarded — the per-block guard is
        unchanged."""
        cfg, plan = self.engine.config, self.plan
        mats = self._materialize_window(b0)
        if cfg.use_sip:
            box_sets = [bx if bx is not None else np.zeros((0, 4))
                        for (_, _, _, bx) in mats]
            v_stars = shard_mod.sip_select(
                self.shards, box_sets, plan.dist_norm, plan.driven_cs,
                self.prepared, plan.probe_backend, plan.descend_backend,
                self.cs_path, cfg.select_params, self.card_all)
            for (w, _, _, _), v_star in zip(mats, v_stars):
                self._vstars[w] = v_star

    def _materialize_window(self, b0: int) -> list[tuple]:
        """Materialize (and cache in `pending`) a lookahead window."""
        mats = [(w,) + self._materialize(w)
                for w in range(b0, min(b0 + self.window, self.n_blocks))]
        for w, drv_rel, uniq_ents, boxes in mats:
            self.pending[w] = (drv_rel, uniq_ents, boxes)
        return mats

    def _process(self, drv_rel, uniq_ents, boxes, v_star,
                 batcher=None) -> None:
        """APS + driven retrieval + Phase-3 join for one materialized block.

        With `batcher` (serve mode, fused backend) the streaming join is
        REGISTERED with the cross-query batcher instead of running here —
        the batcher's emit callback refines + scores + pushes into this
        cursor's TopK so θ tightens between shared kernel launches.

        ``v_star`` is a per-shard list aligned with ``self.shards``. The
        shard-clipped SIP intervals partition the driven result set, so
        sweeping shards sequentially and re-reading θ before each shard's
        APS `key_needed` (global-θ exchange) is exact: earlier shards'
        pushes only tighten later shards' pruning, never change the union.
        """
        eng = self.engine
        cfg, plan = eng.config, self.plan
        driven = self.driven
        topk, stats = self.topk, self.stats
        if cfg.use_sip and all(len(v) == 0 for v in v_star):
            return  # nothing on the driven side can join this block
        stats.v_star_sizes.append(sum(len(v) for v in v_star))
        for si, sh in enumerate(self.shards):
            if cfg.use_sip and len(v_star[si]) == 0:
                continue
            intervals, explicit = sh.filter_material(v_star[si])

            # ---- APS plan decision ----------------------------------
            # θ re-read per shard: the cross-shard pruning exchange
            key_needed = (topk.theta
                          - (self._driver_primary_best + self.driver_other)
                          - eng._side_bound(driven, plan.descending, True)) \
                if topk.full else -np.inf
            decision = aps.choose(sh.tree, v_star[si], plan.driven_cs,
                                  driven.scan, key_needed, drv_rel.n,
                                  cfg.cost_params, self.card_all[si])
            chosen = cfg.force_plan or decision.plan
            if driven.scan is None:
                chosen = "S"
            stats.plan_log.append(chosen)
            if chosen == "N":
                stats.plan_n += 1
                dvn_rel = eng._driven_nplan(driven, plan, intervals,
                                            explicit, key_needed, stats)
            else:
                stats.plan_s += 1
                dvn_rel = eng._driven_splan(driven, plan, intervals,
                                            explicit, stats)
            if dvn_rel.n:
                self._phase3(drv_rel, uniq_ents, boxes, dvn_rel,
                             batcher=batcher)

    def _phase3(self, drv_rel, uniq_ents, boxes, dvn_rel,
                batcher=None) -> None:
        """Phase-3 spatial join + refinement of one driven relation."""
        eng = self.engine
        cfg, plan = eng.config, self.plan
        driver, driven = self.driver, self.driven
        topk, stats = self.topk, self.stats
        dvn_ents = np.unique(dvn_rel[driven.entity_var])
        dvn_boxes = eng.store.spatial_box_of(dvn_ents)
        ok = ~np.isnan(dvn_boxes[:, 0])
        dvn_ents, dvn_boxes = dvn_ents[ok], dvn_boxes[ok]
        if len(dvn_ents) == 0:
            return
        if cfg.mbr_join_fn is None and plan.join_backend == "fused":
            # streaming fused path: driven columns arrive in score-key
            # order, each batch refined+scored+pushed before the next so
            # the θ the kernel prunes with tightens inside the block
            ds = eng._entity_key_bound(drv_rel, uniq_ents, driver, plan)
            vs = eng._entity_key_bound(dvn_rel, dvn_ents, driven, plan)

            def emit(pi, pj):
                eng._emit_pairs(pi, pj, uniq_ents, dvn_ents, drv_rel,
                                dvn_rel, driver, driven, plan, topk,
                                stats, ds=ds, vs=vs)

            if batcher is not None:
                batcher.add(spatial_join.StreamEntry(
                    boxes, dvn_boxes, ds, vs, plan.dist_norm, plan.k,
                    theta_fn=lambda: topk.theta, emit=emit,
                    stats=stats.join))
                return
            for pi, pj in spatial_join.fused_stream_join(
                    boxes, dvn_boxes, ds, vs, plan.dist_norm, k=plan.k,
                    theta_fn=lambda: topk.theta,
                    batch_cols=cfg.fused_batch_cols, stats=stats.join,
                    tuner=eng.kcap_tuner):
                emit(pi, pj)
        else:
            join_fn = cfg.mbr_join_fn or spatial_join.mbr_distance_join
            # the MBR pair set is pure in (boxes, driven boxes, distance),
            # so same-shape tenants share it too; a cache hit skips the
            # per-launch JoinStats counters (they count work done, and a
            # hit does none)
            sc = eng.share_cache
            key = None
            if sc is not None and cfg.mbr_join_fn is None:
                key = ("mbr", plan.join_backend, boxes.shape,
                       dvn_boxes.shape, boxes.tobytes(),
                       dvn_boxes.tobytes(), float(plan.dist_norm))
            if key is not None and key in sc:
                pi, pj = sc[key]
            else:
                pi, pj = join_fn(boxes, dvn_boxes, plan.dist_norm,
                                 plan.join_backend, stats.join)
                if key is not None:
                    sc[key] = (pi, pj)
            eng._emit_pairs(pi, pj, uniq_ents, dvn_ents, drv_rel,
                            dvn_rel, driver, driven, plan, topk, stats)

    # -- serial mode ----------------------------------------------------
    def step(self) -> None:
        """Advance one driver block (internal lookahead SIP prefetch)."""
        if self.done:
            return
        b = self.b
        if not self._block_guard(b):
            return
        self.stats.driver_blocks += 1
        if b not in self.pending:
            self.pending.clear()
            self._vstars.clear()
            self._sip_prefetch(b)
        drv_rel, uniq_ents, boxes = self.pending.pop(b)
        v_star = self._vstars.pop(
            b, [np.array([0], dtype=np.int64)] * len(self.shards))
        self.b += 1
        if drv_rel.n and uniq_ents is not None and len(uniq_ents):
            self._process(drv_rel, uniq_ents, boxes, v_star)
        if self.b >= self.n_blocks:
            self._finish()

    # -- serve mode (two-phase step) ------------------------------------
    def begin_block(self) -> dict | None:
        """Advance to the next live block and materialize it (serve mode).

        Returns None when the cursor is finished, else a Phase-1/2 request
        the serving engine batches across queries::

            {"boxes": [(M_i, 4) driver MBRs, ...], "driven_cs": (C,) int64,
             "prepared": PreparedKeys, "dist_norm": float,
             "card_all": [(N_s,) float64 per shard], "need_sip": bool,
             "cs_path": [(N_s,) bool per shard] | None}

        ``card_all``/``cs_path`` carry one entry per shard view (a 1-list
        on unsharded stores). ``cs_path`` is this query's precomputed
        root-path Bloom mask (set on fused-descent routes, None on the host
        frontier) — the server passes it through so pooled descents skip
        the per-step Bloom probes.

        ``boxes`` covers this block plus the cursor's `sip_lookahead`
        speculative window (one row per block), so each tenant keeps the
        serial path's amortization — one shared frontier pass per refill —
        while the server pools rows across tenants. On steps served from
        the window cache ``need_sip`` is False and ``boxes`` is empty.

        Follow with ``finish_block(v_stars, batcher)`` where ``v_stars`` is
        the per-row V* list for this request (None when ``need_sip`` was
        False).
        """
        assert self._cur is None, "finish_block() the previous block first"
        while not self.done:
            b = self.b
            if not self._block_guard(b):
                return None
            self.stats.driver_blocks += 1
            self.b += 1
            if b not in self.pending:
                self.pending.clear()
                self._vstars.clear()
                self._materialize_window(b)
            drv_rel, uniq_ents, boxes = self.pending.pop(b)
            if drv_rel.n and uniq_ents is not None and len(uniq_ents):
                self._cur = (b, drv_rel, uniq_ents, boxes)
                need_sip = (bool(self.engine.config.use_sip)
                            and b not in self._vstars)
                if need_sip:
                    self._win_blocks = [b] + sorted(self.pending)
                    win_boxes = [boxes] + [
                        self.pending[w][2] if self.pending[w][2] is not None
                        else np.zeros((0, 4)) for w in sorted(self.pending)]
                else:
                    self._win_blocks, win_boxes = [], []
                return {"boxes": win_boxes,
                        "driven_cs": self.plan.driven_cs,
                        "prepared": self.prepared,
                        "dist_norm": self.plan.dist_norm,
                        "card_all": self.card_all,
                        "need_sip": need_sip,
                        "cs_path": self.cs_path}
            if self.b >= self.n_blocks:
                self._finish()
        return None

    def finish_block(self, v_stars: list | None, batcher=None) -> None:
        """Run Phases 2'-3 for the block begin_block() materialized.

        ``v_stars`` aligns with the request's ``boxes`` rows; rows past the
        first are the speculative window and are cached for later steps.
        """
        assert self._cur is not None, "begin_block() first"
        b, drv_rel, uniq_ents, boxes = self._cur
        self._cur = None
        if v_stars is not None:
            for w, v in zip(self._win_blocks, v_stars):
                self._vstars[w] = v
            self._win_blocks = []
        v_star = self._vstars.pop(
            b, [np.array([0], dtype=np.int64)] * len(self.shards))
        self._process(drv_rel, uniq_ents, boxes, v_star, batcher=batcher)
        if self.b >= self.n_blocks:
            self._finish()
