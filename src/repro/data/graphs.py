"""Graph substrate: generators, CSR neighbor sampling, spatial graphs.

- `random_power_law_graph`: degree-skewed synthetic graphs (Reddit-like).
- `NeighborSampler`: real layered uniform sampling over CSR (GraphSAGE
  `minibatch_lg` regime) producing fixed-size padded blocks for jit.
- `spatial_graph` / `grid_mesh_edges`: cutoff graphs + GraphCast grid<->mesh
  edges built with the STREAK Z-order radius join (core.squadtree) — the
  paper's distance join as graph construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import squadtree


def random_power_law_graph(n: int, avg_degree: int, seed: int = 0,
                           alpha: float = 1.8):
    """Edge list (2, E) with power-law out-degrees, deduplicated."""
    rng = np.random.default_rng(seed)
    deg = np.minimum(rng.zipf(alpha, size=n) * avg_degree // 2, n - 1)
    deg = np.maximum(deg, 1)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, size=len(src))
    keep = src != dst
    edges = np.stack([src[keep], dst[keep]]).astype(np.int32)
    key = edges[0].astype(np.int64) * n + edges[1]
    _, idx = np.unique(key, return_index=True)
    return edges[:, idx]


def to_csr(edges: np.ndarray, n: int):
    """(2, E) -> (indptr, indices) over dst-grouped incoming edges."""
    order = np.argsort(edges[1], kind="stable")
    src, dst = edges[0][order], edges[1][order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, dst + 1, 1)
    return np.cumsum(indptr), src


@dataclasses.dataclass
class SampledBlock:
    nodes: np.ndarray      # (n_pad,) global node ids (padded with -1)
    feats: np.ndarray      # (n_pad, F)
    edges: np.ndarray      # (2, e_pad) LOCAL indices into `nodes`
    labels: np.ndarray     # (n_pad,)
    mask: np.ndarray       # (n_pad,) True for real seed nodes


class NeighborSampler:
    """Layered uniform neighbor sampling (GraphSAGE) with fixed padding."""

    def __init__(self, edges: np.ndarray, n: int, feats: np.ndarray,
                 labels: np.ndarray, fanouts: tuple, seed: int = 0):
        self.indptr, self.indices = to_csr(edges, n)
        self.n = n
        self.feats = feats
        self.labels = labels
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """(len(nodes), fanout) sampled in-neighbors (self-pad when none)."""
        starts = self.indptr[nodes]
        counts = self.indptr[nodes + 1] - starts
        pick = self.rng.integers(0, np.maximum(counts, 1)[:, None],
                                 size=(len(nodes), fanout))
        idx = starts[:, None] + pick % np.maximum(counts, 1)[:, None]
        neigh = self.indices[np.minimum(idx, len(self.indices) - 1)]
        neigh = np.where(counts[:, None] > 0, neigh, nodes[:, None])
        return neigh

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        b = len(seeds)
        layers = [seeds]
        edge_src, edge_dst = [], []
        frontier = seeds
        for fanout in self.fanouts:
            neigh = self._sample_neighbors(frontier, fanout)   # (f_n, fanout)
            edge_src.append(neigh.reshape(-1))
            edge_dst.append(np.repeat(frontier, fanout))
            frontier = neigh.reshape(-1)
            layers.append(frontier)
        all_nodes = np.concatenate(layers)
        uniq, inv = np.unique(all_nodes, return_inverse=True)
        # local relabeling
        lut = {g: i for i, g in enumerate(uniq)}
        src = np.concatenate(edge_src)
        dst = np.concatenate(edge_dst)
        src_l = np.searchsorted(uniq, src)
        dst_l = np.searchsorted(uniq, dst)
        # fixed padded sizes (jit-stable shapes)
        n_pad = b * (1 + int(np.prod([1] + list(self.fanouts))) * 0 +
                     sum(int(np.prod(self.fanouts[:i + 1]))
                         for i in range(len(self.fanouts))))
        n_pad = max(n_pad, len(uniq))
        e_pad = sum(b * int(np.prod(self.fanouts[:i + 1]))
                    for i in range(len(self.fanouts)))
        nodes = np.full(n_pad, -1, dtype=np.int64)
        nodes[: len(uniq)] = uniq
        feats = np.zeros((n_pad, self.feats.shape[1]), self.feats.dtype)
        feats[: len(uniq)] = self.feats[uniq]
        labels = np.zeros(n_pad, dtype=np.int32)
        labels[: len(uniq)] = self.labels[uniq]
        edges = np.zeros((2, e_pad), dtype=np.int32)
        edges[0, : len(src_l)] = src_l
        edges[1, : len(dst_l)] = dst_l
        mask = np.zeros(n_pad, dtype=bool)
        mask[np.searchsorted(uniq, seeds)] = True
        return SampledBlock(nodes, feats, edges, labels, mask)


def spatial_graph(positions: np.ndarray, cutoff: float,
                  include_self: bool = False) -> np.ndarray:
    """Cutoff graph via the STREAK Z-order radius join. positions (N, d<=3):
    the join runs on the first two dims; 3-d distances are refined exactly."""
    p2 = positions[:, :2]
    i, j = squadtree.radius_join(p2, p2, cutoff, include_self=include_self)
    if positions.shape[1] > 2:
        d = np.sqrt(((positions[i] - positions[j]) ** 2).sum(-1))
        keep = d <= cutoff
        i, j = i[keep], j[keep]
    return np.stack([i, j]).astype(np.int32)


def grid_mesh_edges(grid_xy: np.ndarray, mesh_xy: np.ndarray,
                    radius: float) -> np.ndarray:
    """GraphCast grid->mesh bipartite edges via the radius join."""
    i, j = squadtree.radius_join(grid_xy, mesh_xy, radius)
    return np.stack([i, j]).astype(np.int32)
