"""The block spatial join: Phases 1-3 + refinement (paper §3.2).

Phase 1 (candidate nodes) lives on SQuadTree.candidate_nodes; Phase 2 is
node_select.select + SIP filter material; this module is Phase 3 — the
pairwise MBR distance join between a driver block and the SIP-filtered driven
candidates — plus the exact-geometry refinement step.

The MBR join is the compute hot spot; on TPU it runs through the
`distance_join` Pallas kernel (kernels/distance_join.py); the numpy path here
is the portable fallback and the oracle for tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import geometry


@dataclasses.dataclass
class JoinStats:
    candidates: int = 0     # MBR-level candidate pairs emitted
    refined: int = 0        # pairs surviving exact refinement
    pairs_tested: int = 0   # full MBR pairs evaluated (block product)


def mbr_distance_join(driver_boxes: np.ndarray, driven_boxes: np.ndarray,
                      dist_norm: float, backend: str = "numpy",
                      stats: JoinStats | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Candidate pairs (i, j) with box_min_dist <= dist (normalized space)."""
    if len(driver_boxes) == 0 or len(driven_boxes) == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if backend == "kernel":
        from ..kernels import ops as kops
        mask = np.asarray(kops.distance_join_mask(
            driver_boxes.astype(np.float32), driven_boxes.astype(np.float32),
            float(dist_norm)))
    else:
        d = geometry.box_min_dist(driver_boxes[:, None, :],
                                  driven_boxes[None, :, :])
        mask = d <= dist_norm
    if stats is not None:
        stats.pairs_tested += mask.size
        stats.candidates += int(mask.sum())
    i, j = np.nonzero(mask)
    return i.astype(np.int64), j.astype(np.int64)


def refine(pairs_i: np.ndarray, pairs_j: np.ndarray,
           driver_geom: list, driven_geom: list,
           dist_world: float, metric: str = "euclid",
           stats: JoinStats | None = None) -> np.ndarray:
    """Exact-representation distance validation (paper §3.2.4).

    driver_geom / driven_geom are per-candidate exact geometries: (m, 2) point
    arrays (points, polylines, polygon rings). Returns a boolean keep mask.
    """
    keep = np.zeros(len(pairs_i), dtype=bool)
    dist_fn = geometry.euclid_dist if metric == "euclid" else geometry.haversine_km
    for n in range(len(pairs_i)):
        pa = driver_geom[n]
        pb = driven_geom[n]
        d = dist_fn(pa[:, None, :], pb[None, :, :])
        keep[n] = bool((d <= dist_world).any())
    if stats is not None:
        stats.refined += int(keep.sum())
    return keep


def exact_pair_distance(driver_geom: list, driven_geom: list,
                        metric: str = "euclid") -> np.ndarray:
    dist_fn = geometry.euclid_dist if metric == "euclid" else geometry.haversine_km
    out = np.empty(len(driver_geom))
    for n in range(len(driver_geom)):
        d = dist_fn(driver_geom[n][:, None, :], driven_geom[n][None, :, :])
        out[n] = float(d.min())
    return out
