"""sasrec [arXiv:1808.09781; paper]: embed_dim=50 2 blocks 1 head seq 50,
self-attentive sequential recommendation. Catalog 10^6 items
(retrieval_cand scores 1M candidates)."""
from ..models.sasrec import SASRecConfig
from .registry import RECSYS_SHAPES as SHAPES  # noqa: F401

FAMILY = "recsys"
CONFIG = SASRecConfig(name="sasrec", n_items=1_000_000, embed_dim=50,
                      n_blocks=2, n_heads=1, seq_len=50, d_ff=50)
SMOKE = SASRecConfig(name="sasrec-smoke", n_items=1000, embed_dim=16,
                     n_blocks=2, n_heads=1, seq_len=10, d_ff=16)
