"""Baseline engines the paper compares against (§4, §5).

- FullScanEngine ("PostgreSQL-like"): full joins of both sub-queries, a
  spatial-index nested-loop filter (cell-list, gist-style), full scoring,
  sort, LIMIT k. No top-k early termination — its runtime is k-independent,
  reproducing the paper's Fig. 12 observation.
- SyncRTreeEngine: the STREAK block pipeline with the S-QuadTree spatial join
  swapped for synchronous R-tree traversal [Brinkhoff '93] and CS/SIP
  disabled — the paper's run-time switch used for Fig. 8.
- Fixed-plan engines: APS disabled, always-N or always-S (Fig. 9 / 12).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import rtree, spatial_join
from .executor import ExecConfig, ExecStats, StreakEngine
from .join import Relation, join, scan_pattern
from .planner import plan_query
from .query import Query
from .store import QuadStore


@dataclasses.dataclass
class BaselineStats:
    rows_joined: int = 0
    pairs_checked: int = 0
    candidates: int = 0


class FullScanEngine:
    """Evaluate everything, sort at the end (no early termination).

    Also the brute-force differential oracle for every non-top-k query
    shape (core/shapes.py): range / within-distance selections, per-driver
    kNN, and the non-top-k spatial join skip all index pruning here — full
    cartesian candidate sets, per-entity python predicate loops — but score
    with the same exact-geometry primitives, so results are bit-identical
    to the engine when (and only when) the engine's pruning is lossless.
    """

    def __init__(self, store: QuadStore):
        self.store = store

    def _full_side(self, side) -> Relation:
        store = self.store
        if not side.all_ordered:
            return Relation({side.entity_var:
                             np.unique(store.tree.obj_ids)})
        rel = scan_pattern(store, side.all_ordered[0])
        for tp in side.all_ordered[1:]:
            rel = join(rel, scan_pattern(store, tp))
        return rel

    def execute(self, q: Query) -> tuple[np.ndarray, Relation, BaselineStats]:
        store = self.store
        stats = BaselineStats()
        if q.spatial is not None and q.shape() != "topk":
            return self._execute_shape(q, stats)
        plan = plan_query(store, q)
        driver, driven = plan.driver, plan.driven
        full_side = self._full_side

        drv = full_side(driver)
        dvn = full_side(driven)
        stats.rows_joined = drv.n + dvn.n

        ua = np.unique(drv[driver.entity_var])
        ub = np.unique(dvn[driven.entity_var])
        ba, bb = store.spatial_box_of(ua), store.spatial_box_of(ub)
        ok_a, ok_b = ~np.isnan(ba[:, 0]), ~np.isnan(bb[:, 0])
        ua, ba = ua[ok_a], ba[ok_a]
        ub, bb = ub[ok_b], bb[ok_b]
        # gist-style filter: cell-list candidate pairs on MBR centroids
        from .squadtree import radius_join
        ca = (ba[:, :2] + ba[:, 2:]) * 0.5
        cb = (bb[:, :2] + bb[:, 2:]) * 0.5
        diag_a = np.sqrt(((ba[:, 2:] - ba[:, :2]) ** 2).sum(1))
        diag_b = np.sqrt(((bb[:, 2:] - bb[:, :2]) ** 2).sum(1))
        slack = float(diag_a.max(initial=0.0) + diag_b.max(initial=0.0)) / 2.0
        pi, pj = radius_join(ca, cb, plan.dist_norm + slack)
        stats.pairs_checked = len(pi)
        keep = spatial_join.refine(
            pi, pj, store.geom_pool,
            store.geom_rows(ua[pi]), store.geom_rows(ub[pj]),
            plan.dist_world, plan.metric)
        pi, pj = pi[keep], pj[keep]
        stats.candidates = len(pi)
        pair_rel = Relation({driver.entity_var: ua[pi],
                             driven.entity_var: ub[pj]})
        out = join(join(drv, pair_rel), dvn)
        # full scoring + sort + LIMIT k
        keys = np.zeros(out.n)
        for side in (driver, driven):
            for tp, var, w in side.quant_terms:
                kw = w if plan.descending else -w
                keys += kw * store.values_of(out[var])
        valid = ~np.isnan(keys)
        out, keys = out.take(np.flatnonzero(valid)), keys[valid]
        order = np.argsort(-keys, kind="stable")[: plan.k]
        scores = keys[order] if plan.descending else -keys[order]
        return scores, out.take(order), stats

    # -- non-top-k shape oracles (core/shapes.py differential targets) ----
    def _execute_shape(self, q: Query, stats: BaselineStats):
        from . import shapes
        store = self.store
        plan = plan_query(store, q)
        shape = plan.shape
        pool = store.geom_pool

        def ents_of(rel, var):
            return shapes._ents_boxes(store, rel, var)[0]

        def geom_slices(ents):
            rows = store.geom_rows(ents)
            off = pool.offsets
            return [pool.points[off[r]:off[r + 1]].astype(np.float64)
                    for r in rows]

        drv = self._full_side(plan.driver)
        stats.rows_joined += drv.n
        a_ents = ents_of(drv, plan.driver.entity_var)

        if shape == "range":
            xmin, ymin, xmax, ymax = (float(v) for v in q.spatial.window)
            hit = np.array(
                [bool(((g[:, 0] >= xmin) & (g[:, 0] <= xmax)
                       & (g[:, 1] >= ymin) & (g[:, 1] <= ymax)).any())
                 for g in geom_slices(a_ents)], dtype=bool) \
                if len(a_ents) else np.zeros(0, dtype=bool)
            qual = a_ents[hit]
            stats.candidates = len(qual)
            scores, rows = shapes._select_rows(
                drv, plan.driver.entity_var, qual, np.zeros(len(qual)))
            return scores, rows, stats

        if shape == "within":
            from . import geometry
            c = np.asarray(q.spatial.center, dtype=np.float64)
            dist_fn = (geometry.haversine_km if plan.metric == "haversine"
                       else geometry.euclid_dist)
            d = np.array([float(dist_fn(g, c[None, :]).min())
                          for g in geom_slices(a_ents)], dtype=np.float64) \
                if len(a_ents) else np.zeros(0)
            ok = d <= float(plan.dist_world)
            qual, dq = a_ents[ok], d[ok]
            stats.candidates = len(qual)
            scores, rows = shapes._select_rows(
                drv, plan.driver.entity_var, qual, dq)
            return scores, rows, stats

        # binary shapes: full cartesian candidate pairs, exact distances
        dvn = self._full_side(plan.driven)
        stats.rows_joined += dvn.n
        b_ents = ents_of(dvn, plan.driven.entity_var)
        na, nb = len(a_ents), len(b_ents)
        pi = np.repeat(np.arange(na, dtype=np.int64), nb)
        pj = np.tile(np.arange(nb, dtype=np.int64), na)
        stats.pairs_checked = len(pi)
        d = spatial_join.exact_pair_distance(
            pool, store.geom_rows(a_ents)[pi], store.geom_rows(b_ents)[pj],
            plan.metric)

        if shape == "join":
            ok = d <= float(plan.dist_world)
            pi, pj, d = pi[ok], pj[ok], d[ok]
        else:   # knn: k smallest per driver by (distance, driven entity)
            k = int(q.spatial.knn)
            order = np.lexsort((b_ents[pj], d, pi))
            pi, pj, d = pi[order], pj[order], d[order]
            first = np.r_[True, pi[1:] != pi[:-1]] if len(pi) \
                else np.zeros(0, dtype=bool)
            grp = np.flatnonzero(first)
            width = np.diff(np.r_[grp, len(pi)])
            rank = np.arange(len(pi), dtype=np.int64) - np.repeat(grp, width)
            sel = rank < k
            pi, pj, d = pi[sel], pj[sel], d[sel]
        stats.candidates = len(pi)
        scores, rows = shapes._assemble_pairs(
            plan, drv, dvn, a_ents[pi], b_ents[pj], d)
        return scores, rows, stats


class SyncRTreeEngine(StreakEngine):
    """STREAK with the spatial join swapped for sync R-tree traversal.

    CS pruning and SIP are disabled (an R-tree has neither); the driven side
    is always the full driven sub-query (S-Plan shape without SIP). Candidate
    counts are recorded for the Fig. 8 comparison.
    """

    def __init__(self, store: QuadStore, config: ExecConfig | None = None,
                 fanout: int = 16):
        cfg = config or ExecConfig()
        cfg = dataclasses.replace(cfg, use_sip=False, force_plan="S")
        super().__init__(store, cfg)
        self.fanout = fanout
        self._driven_tree_cache: dict = {}

    def _rtree_of(self, key, boxes: np.ndarray) -> rtree.RTree:
        if key not in self._driven_tree_cache:
            self._driven_tree_cache[key] = rtree.build_str(boxes, self.fanout)
        return self._driven_tree_cache[key]

    def execute(self, q: Query):
        # reuse the full pipeline; only the Phase-3 MBR join differs
        self._sync_stats = rtree.SyncJoinStats()
        engine = self

        def rtree_join(driver_boxes, driven_boxes, dist_norm,
                       backend="numpy", stats=None):
            ta = rtree.build_str(driver_boxes, engine.fanout)
            tb = rtree.build_str(driven_boxes, engine.fanout)
            i, j = rtree.sync_distance_join(ta, tb, dist_norm,
                                            engine._sync_stats)
            if stats is not None:
                stats.candidates += len(i)
                stats.pairs_tested += engine._sync_stats.node_pairs_visited
            return i, j

        self.config = dataclasses.replace(self.config, mbr_join_fn=rtree_join)
        return super().execute(q)


def fixed_plan_engine(store: QuadStore, plan: str,
                      config: ExecConfig | None = None) -> StreakEngine:
    cfg = config or ExecConfig()
    return StreakEngine(store, dataclasses.replace(cfg, force_plan=plan))
