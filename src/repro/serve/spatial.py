"""Multi-tenant spatial-query serving: continuous batching over STREAK.

The LM decode loop in serve/engine.py generalizes directly: a fixed pool of
`max_slots` slots, each holding one query's `QueryCursor`; waiting requests
claim free slots, every engine step advances EVERY active slot by one driver
block, and a query that θ-terminates (or exhausts its driver scan) releases
its slot mid-flight for the next queued request — continuous batching, with
"one decoded token" replaced by "one driver block".

What actually batches across tenants per step:

- **Phases 1-2** — every slot's `begin_block()` request is pooled into ONE
  `candidate_nodes` call (per-block driven-CS sets + per-block distances;
  slots of the same query shape share Bloom probes) and ONE `select_batch`
  call with a stacked per-row cost matrix.
- **Phase 3** — with the fused join backend, every slot's streaming join
  registers with a `_FusedJoinBatcher`; one `fused_stream_join_multi` run
  then launches all live queries' driver blocks in shared kernel grids with
  per-row (distance, θ, query-id) state, each query's partial results
  feeding back into its own TopK between launches.

θ pruning is sound at any batching granularity, so per-query results are
bit-identical to serial `StreakEngine.execute` runs — the stress tests
assert exactly that.

Fault tolerance (core/fault.py holds the primitives): each slot's
`begin_block`/`finish_block` is crash-isolated, so one tenant's exception
retires only that request — transient failures (`fault.TRANSIENT`) restart
from a FRESH cursor after an exponential tick backoff (a faulted cursor's
TopK may hold a partial batch; resuming it could double-push), permanent
ones land on `SpatialRequest.error` with empty results. A poisoned pooled
Phase-1/2 call falls back to per-slot serial execution for that step, and a
faulted entry in the shared Phase-3 batch (`StreamEntry.error`) faults only
its rider. Per-request `QueryDeadline`s pass through to the cursor, so an
expired tenant retires with `stats.partial` anytime results.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import fault, node_select, shard as shard_mod, spatial_join
from ..core.executor import ExecStats, QueryCursor, StreakEngine
from ..core.join import Relation
from ..core.query import Query


@dataclasses.dataclass
class SpatialRequest:
    rid: int
    query: Query
    scores: np.ndarray | None = None
    rows: Relation | None = None
    stats: ExecStats | None = None
    done: bool = False
    steps: int = 0                  # engine steps this request stayed active
    waited: int = 0                 # engine steps spent queued
    deadline: fault.QueryDeadline | None = None
    error: Exception | None = None  # set ⟹ retired by a permanent failure
    retries: int = 0                # fresh-cursor restarts consumed
    not_before: int = 0             # earliest engine tick re-admission runs


@dataclasses.dataclass
class ServeStats:
    steps: int = 0                  # engine iterations
    admissions: int = 0             # slot claims (== completed requests)
    released_early: int = 0         # slots freed by θ termination mid-scan
    slot_reuse: int = 0             # admissions beyond the first per slot
    sip_batches: int = 0            # pooled candidate_nodes/select calls
    sip_blocks: int = 0             # driver blocks covered by those calls
    join_launches: int = 0          # cross-query fused kernel launches
    max_queue: int = 0
    faults: int = 0                 # slot exceptions caught (any phase)
    retries: int = 0                # transient faults re-queued with backoff
    failed_requests: int = 0        # requests retired with an error
    admission_failures: int = 0     # cursor construction raised in _admit
    pooled_fallbacks: int = 0       # pooled Phase-1/2 → per-slot serial
    share_evictions: int = 0        # FIFO share-cache entry evictions
    deadline_partials: int = 0      # requests retired with partial results


class _FusedJoinBatcher:
    """Collects every slot's Phase-3 streaming join for one engine step and
    runs them as cross-query `fused_stream_join_multi` launches."""

    def __init__(self, batch_cols: int, tuner=None):
        self.batch_cols = batch_cols
        self.tuner = tuner
        self.entries: list[spatial_join.StreamEntry] = []

    def add(self, entry: spatial_join.StreamEntry) -> None:
        self.entries.append(entry)

    def flush(self) -> int:
        if not self.entries:
            return 0
        launches = spatial_join.fused_stream_join_multi(
            self.entries, batch_cols=self.batch_cols, tuner=self.tuner)
        self.entries = []
        return launches


class SpatialServeEngine:
    """Slot-based admission loop over a shared `StreakEngine`.

    One engine instance per store: the relation scan cache, the Bloom
    `PreparedKeys`, and the kcap autotuner are shared by every tenant.
    """

    def __init__(self, store, config=None, max_slots: int = 8,
                 max_retries: int = 2, share_cache_max: int = 1024):
        self.engine = StreakEngine(store, config)
        # tenants running the same query shape (a hot query with per-user
        # k, say) share θ-independent per-block work: driver-block
        # materialization, S-Plan filtered retrieval, N-Plan block joins
        # (executor.StreakEngine.share_cache) and pooled Phase-1/2 rows
        # (deduped in step()). Serial per-query execution recomputes all
        # of it per tenant.
        self.engine.share_cache = {}
        self.max_slots = max_slots
        self.max_retries = max_retries
        self.share_cache_max = share_cache_max
        self.slots: list[tuple[SpatialRequest, QueryCursor] | None] = \
            [None] * max_slots
        self.queue: list[SpatialRequest] = []
        self.stats = ServeStats()
        self._slot_used = [False] * max_slots
        self._tick = 0                  # backoff clock: one tick per step()

    # ------------------------------------------------------------------
    def submit(self, req: SpatialRequest) -> None:
        self.queue.append(req)

    def _fail(self, req: SpatialRequest, exc: Exception) -> None:
        """Retire `req` with `exc` surfaced and well-typed empty results —
        never silently dropped, never poisoning other tenants."""
        req.error = exc
        req.scores = np.empty(0)
        req.rows = Relation()
        req.stats = ExecStats()
        req.done = True
        self.stats.failed_requests += 1

    def _fault_slot(self, slot: int, exc: Exception) -> None:
        """One tenant crashed: free its slot, and either re-queue it for a
        fresh-cursor restart (transient failures, bounded exponential tick
        backoff) or retire it with the error surfaced. A faulted cursor is
        always discarded — its TopK may hold a partial emit batch, so only
        a restart from scratch preserves bit-identicality."""
        req, _ = self.slots[slot]
        self.slots[slot] = None
        self.stats.faults += 1
        if isinstance(exc, fault.TRANSIENT) and req.retries < self.max_retries:
            req.retries += 1
            self.stats.retries += 1
            req.not_before = self._tick + (1 << (req.retries - 1))
            self.queue.insert(0, req)   # it was admitted earliest: run next
        else:
            self._fail(req, exc)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slots[slot] is not None:
                continue
            i = 0
            while i < len(self.queue):
                req = self.queue[i]
                if req.not_before > self._tick:   # backing off: skip, keep
                    i += 1
                    continue
                self.queue.pop(i)
                try:
                    cur = self.engine.cursor(req.query,
                                             deadline=req.deadline)
                except Exception as exc:    # noqa: BLE001 — surface per-req
                    self.stats.admission_failures += 1
                    self._fail(req, exc)
                    continue                # next queued request, same slot
                self.slots[slot] = (req, cur)
                self.stats.admissions += 1
                if self._slot_used[slot]:
                    self.stats.slot_reuse += 1
                self._slot_used[slot] = True
                break

    def _retire(self, slot: int) -> None:
        req, cur = self.slots[slot]
        req.scores, req.rows, req.stats = cur.results()
        req.done = True
        if cur.stats.early_terminated:
            self.stats.released_early += 1
        if cur.stats.partial:
            self.stats.deadline_partials += 1
        self.slots[slot] = None

    # ------------------------------------------------------------------
    def _slot_sip(self, r: dict) -> list:
        """Per-slot serial Phase-1/2 (the pooled call's degraded mode): the
        same per-shard candidate_nodes + select_batch, one tenant's rows
        only. Returns per-row lists of per-shard V* arrays."""
        shards = shard_mod.shard_views(self.engine.store)
        policy = self.engine.config.policy
        boxes = [b if b is not None else np.zeros((0, 4))
                 for b in r["boxes"]]
        n = len(boxes)
        cs_path = r.get("cs_path")
        sel_shards = []
        for si, sh in enumerate(shards):
            in_v = sh.tree.candidate_nodes(
                boxes, np.full(n, r["dist_norm"]), [r["driven_cs"]] * n,
                prepared=[r["prepared"]] * n,
                probe_backend=policy.probe, descend_backend=policy.descend,
                cs_path=[cs_path[si] if cs_path is not None else None] * n)
            sel_shards.append(node_select.select_batch(
                sh.tree, in_v, [r["driven_cs"]] * n,
                self.engine.config.select_params,
                card_all=np.stack([r["card_all"][si]] * n)))
        self.stats.sip_batches += 1
        self.stats.sip_blocks += n
        return [[sel_shards[si][i] for si in range(len(shards))]
                for i in range(n)]

    def step(self) -> int:
        """One iteration: admit, advance every active slot one driver block
        (Phases 1-2 pooled, Phase 3 cross-query batched), retire finished
        queries. Returns the number of active slots this step.

        Every per-slot phase is crash-isolated: an exception advances only
        that slot to `_fault_slot` (restart or retire) while the rest of the
        step proceeds."""
        self._tick += 1
        self._admit()
        self.stats.max_queue = max(self.stats.max_queue, len(self.queue))
        active = [s for s in range(self.max_slots)
                  if self.slots[s] is not None]
        if not active:
            return 0
        self.stats.steps += 1
        for s in active:
            self.slots[s][0].steps += 1
        for r in self.queue:
            r.waited += 1

        # ---- phase A: materialize one block per slot, pool SIP requests --
        work: list[tuple[int, dict]] = []        # (slot, request)
        for s in active:
            req, cur = self.slots[s]
            try:
                sip_req = cur.begin_block()
            except Exception as exc:    # noqa: BLE001 — isolate the tenant
                self._fault_slot(s, exc)
                continue
            if sip_req is None:                  # finished (θ or exhausted)
                self._retire(s)
                continue
            work.append((s, sip_req))

        sip_slots = [(s, r) for (s, r) in work if r["need_sip"]]
        v_stars: dict[int, list | None] = {s: None for (s, r) in work}
        if sip_slots:
            # one pooled Phase-1/2 call PER SHARD over every tenant's
            # window rows; rows of one tenant share a CS array (and thus
            # one frontier group), different tenants' groups ride the same
            # batch, and identical rows from same-shape tenants collapse
            # to one row — the dedup row set is shard-independent, so the
            # per-shard sweep reuses it as-is
            shards = shard_mod.shard_views(self.engine.store)
            policy = self.engine.config.policy
            boxes, cs_sets, prepared, dists, cards = [], [], [], [], []
            cs_paths = []
            row_of: dict[tuple, int] = {}
            spans: list[tuple[int, list[int]]] = []
            for s, r in sip_slots:
                cs_bytes = np.asarray(r["driven_cs"]).tobytes()
                rows = []
                for box in r["boxes"]:
                    box = box if box is not None else np.zeros((0, 4))
                    rk = (box.shape, box.tobytes(), cs_bytes,
                          float(r["dist_norm"]))
                    idx = row_of.get(rk)
                    if idx is None:
                        idx = len(boxes)
                        row_of[rk] = idx
                        boxes.append(box)
                        cs_sets.append(r["driven_cs"])
                        prepared.append(r["prepared"])
                        dists.append(r["dist_norm"])
                        cards.append(r["card_all"])
                        # tenants' precomputed root-path masks ride along so
                        # fused descents skip the per-step Bloom probes
                        cs_paths.append(r.get("cs_path"))
                    rows.append(idx)
                spans.append((s, rows))
            try:
                # cards[i] / cs_paths[i] are per-shard lists (tenant
                # cursors expose one entry per shard view, same order)
                sel_shards = []
                for si, sh in enumerate(shards):
                    in_v = sh.tree.candidate_nodes(
                        boxes, np.array(dists), cs_sets,
                        prepared=prepared,
                        probe_backend=policy.probe,
                        descend_backend=policy.descend,
                        cs_path=[p[si] if p is not None else None
                                 for p in cs_paths])
                    sel_shards.append(node_select.select_batch(
                        sh.tree, in_v, cs_sets,
                        self.engine.config.select_params,
                        card_all=np.stack([c[si] for c in cards])))
                for s, rows in spans:
                    v_stars[s] = [[sel_shards[si][i]
                                   for si in range(len(shards))]
                                  for i in rows]
                self.stats.sip_batches += 1
                self.stats.sip_blocks += len(boxes)
            except Exception:       # noqa: BLE001 — poisoned pooled call
                # one tenant's rows poisoned the shared batch: degrade to
                # per-slot serial Phase-1/2 for this step, so only the
                # culprit faults and the rest keep their V* (bit-identical:
                # candidate_nodes/select_batch are per-row functions)
                self.stats.pooled_fallbacks += 1
                for s, r in sip_slots:
                    try:
                        v_stars[s] = self._slot_sip(r)
                    except Exception as exc:    # noqa: BLE001
                        self._fault_slot(s, exc)

        # ---- phase B: APS + driven retrieval + Phase-3 -------------------
        batcher = None
        if self.engine.config.policy.join == "fused" \
                and self.engine.config.mbr_join_fn is None:
            batcher = _FusedJoinBatcher(self.engine.config.fused_batch_cols,
                                        tuner=self.engine.kcap_tuner)
        entry_spans: dict[int, slice] = {}       # slot -> its batcher entries
        for s, _ in work:
            if self.slots[s] is None:            # faulted in phase A
                continue
            req, cur = self.slots[s]
            n0 = len(batcher.entries) if batcher is not None else 0
            try:
                cur.finish_block(v_stars[s], batcher=batcher)
            except Exception as exc:    # noqa: BLE001 — isolate the tenant
                if batcher is not None:          # roll back registrations
                    del batcher.entries[n0:]
                self._fault_slot(s, exc)
                continue
            if batcher is not None:
                entry_spans[s] = slice(n0, len(batcher.entries))
        if batcher is not None:
            entries = list(batcher.entries)
            try:
                self.stats.join_launches += batcher.flush()
            except Exception as exc:    # noqa: BLE001 — launch-level crash
                for e in entries:
                    if e.error is None:
                        e.error = exc
            # faulted entries (StreamEntry.error) fault only their riders
            for s, span in entry_spans.items():
                errs = [e.error for e in entries[span] if e.error is not None]
                if errs and self.slots[s] is not None:
                    self._fault_slot(s, errs[0])
        for s, _ in work:
            if self.slots[s] is not None and self.slots[s][1].done:
                self._retire(s)
        # bound the cross-tenant memo (entries hold relations) with
        # insertion-order eviction: dicts iterate oldest-first, so popping
        # from the front drops the stalest per-block results while this
        # step's hot entries survive
        sc = self.engine.share_cache
        if sc is not None:
            while len(sc) > self.share_cache_max:
                sc.pop(next(iter(sc)))
                self.stats.share_evictions += 1
        return len(active)

    def run(self) -> None:
        while self.queue or any(sl is not None for sl in self.slots):
            if self.step() == 0 and not self.queue:
                break

    # ------------------------------------------------------------------
    def serve(self, queries: list[Query]) -> list[SpatialRequest]:
        """Convenience: submit all, run to completion, return requests in
        submission order."""
        reqs = [SpatialRequest(rid=i, query=q) for i, q in enumerate(queries)]
        for r in reqs:
            self.submit(r)
        self.run()
        return reqs
