"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]: 48L d_model=2048 32H
(GQA kv=4) per-expert d_ff=768 vocab=151936, MoE 128 routed top-8."""
from ..models.moe import MoEConfig
from .registry import LM_SHAPES as SHAPES  # noqa: F401

FAMILY = "moe"
CONFIG = MoEConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, head_dim=128, vocab=151936,
    n_experts=128, n_experts_padded=128, top_k=8, d_ff_expert=768,
    n_shared=0, act="silu", norm="rms", rope_theta=1e6,
    dtype="bfloat16", remat=True, loss_chunks=16)
SMOKE = MoEConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, vocab=256, n_experts=8, n_experts_padded=8,
    top_k=8, d_ff_expert=32, n_shared=0, act="silu", norm="rms",
    dtype="float32", remat=False)
