"""Mixture-of-Experts transformer (qwen2-moe-a2.7b, qwen3-moe-30b-a3b).

Routing: softmax top-k with optional shared experts (qwen2-moe: 4 shared +
60 routed top-4; qwen3-moe: 128 routed top-8). Dispatch is SORT-BASED with a
fixed per-expert capacity (dropless up to the capacity factor): token->expert
pairs are ranked within their expert via an argsort, gathered into an
(E, C, D) buffer, pushed through per-expert GEMMs, and scatter-added back
weighted by the router probability. No (T, E, C) one-hot tensor is ever
materialized (GShard-style einsum dispatch is O(T*E*C) memory — hopeless at
65k tokens/device).

Sharding contract: the expert axis E maps to the logical "model" axis
(expert parallelism); tokens stay replicated across "model" for routing, and
the scatter-add back is a partial-sum that XLA turns into a psum over the
expert shards. E is zero-padded to a multiple of the mesh axis when needed
(qwen2-moe: 60 -> 64) and the router masks padding experts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers, transformer
from .layers import activation, apply_norm, dense_init, init_norm, rope
from .transformer import TransformerConfig, _attention


@dataclasses.dataclass(frozen=True)
class MoEConfig(TransformerConfig):
    n_experts: int = 8          # routed experts (logical, pre-padding)
    n_experts_padded: int = 8   # physical experts (divisible by mesh "model")
    top_k: int = 2
    d_ff_expert: int = 512
    n_shared: int = 0           # shared experts, each of d_ff_expert width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    def n_params(self) -> int:
        qkv = self.d_model * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
        o = self.n_heads * self.head_dim * self.d_model
        moe = self.n_experts * 3 * self.d_model * self.d_ff_expert
        shared = self.n_shared * 3 * self.d_model * self.d_ff_expert
        router = self.d_model * self.n_experts
        per_layer = qkv + o + moe + shared + router
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    def n_active_params(self) -> int:
        qkv = self.d_model * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
        o = self.n_heads * self.head_dim * self.d_model
        moe = self.top_k * 3 * self.d_model * self.d_ff_expert
        shared = self.n_shared * 3 * self.d_model * self.d_ff_expert
        router = self.d_model * self.n_experts
        per_layer = qkv + o + moe + shared + router
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb


def init_params(key, cfg: MoEConfig):
    dt = cfg.jdtype
    ks = layers.split_keys(key, 12)
    L, D, H, Hk, Dh = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                       cfg.n_kv_heads, cfg.head_dim)
    E, Fe = cfg.n_experts_padded, cfg.d_ff_expert

    def stack(k, shape):
        return dense_init(k, (L,) + shape, in_axis=1, dtype=dt)

    params = {
        "embed": dense_init(ks[0], (cfg.vocab, D), in_axis=1, dtype=dt),
        "layers": {
            "wq": stack(ks[1], (D, H * Dh)),
            "wk": stack(ks[2], (D, Hk * Dh)),
            "wv": stack(ks[3], (D, Hk * Dh)),
            "wo": stack(ks[4], (H * Dh, D)),
            "router": stack(ks[5], (D, E)),
            # per-expert SwiGLU weights, expert axis ("model"-sharded)
            "we_gate": dense_init(ks[6], (L, E, D, Fe), in_axis=2, dtype=dt),
            "we_up": dense_init(ks[7], (L, E, D, Fe), in_axis=2, dtype=dt),
            "we_down": dense_init(ks[8], (L, E, Fe, D), in_axis=2, dtype=dt),
            "ln1": jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                                init_norm(cfg.norm, D)),
            "ln2": jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                                init_norm(cfg.norm, D)),
        },
        "final_norm": init_norm(cfg.norm, D),
    }
    if cfg.n_shared:
        Fs = cfg.n_shared * Fe
        params["layers"]["ws_gate"] = stack(ks[9], (D, Fs))
        params["layers"]["ws_up"] = stack(ks[10], (D, Fs))
        params["layers"]["ws_down"] = stack(ks[11], (Fs, D))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[11], (D, cfg.vocab), in_axis=0,
                                       dtype=dt)
    return params


def moe_ffn(lp, x: jnp.ndarray, cfg: MoEConfig):
    """x (T, D) -> (y (T, D), aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts_padded, cfg.top_k
    cap = int(max(1, round(t * k / cfg.n_experts * cfg.capacity_factor)))
    cap = min(cap, t)
    logits = (x @ lp["router"]).astype(jnp.float32)          # (T, E)
    if cfg.n_experts_padded != cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize

    # --- sort-based dispatch -------------------------------------------
    flat_e = top_e.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert segment = position - segment start
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    rank = jnp.arange(t * k) - seg_start[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)          # overflow -> sink
    # gather tokens into (E, C, D); sink row is zeros
    token_of_slot = jnp.full((e * cap + 1,), t, dtype=jnp.int32)  # t = pad row
    token_of_slot = token_of_slot.at[slot].set(
        jnp.where(keep, st_, t).astype(jnp.int32))
    weight_of_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0))
    token_of_slot = token_of_slot[:-1]
    weight_of_slot = weight_of_slot[:-1]
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = x_pad[token_of_slot].reshape(e, cap, d)
    # --- per-expert GEMMs (E sharded over "model") -----------------------
    gate = jnp.einsum("ecd,edf->ecf", xg, lp["we_gate"])
    up = jnp.einsum("ecd,edf->ecf", xg, lp["we_up"])
    h = activation(gate, cfg.act) * up
    y_slots = jnp.einsum("ecf,efd->ecd", h, lp["we_down"]).reshape(e * cap, d)
    y_slots = y_slots * weight_of_slot[:, None].astype(y_slots.dtype)
    # --- combine: scatter-add back to tokens ----------------------------
    y = jnp.zeros((t + 1, d), y_slots.dtype).at[token_of_slot].add(y_slots)[:t]

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return y.astype(x.dtype), aux


def _layer(lp, x, cfg: MoEConfig, positions):
    b, s, d = x.shape
    x = layers.shard_activations(x, cfg.batch_axes, cfg.seq_axes)
    h = apply_norm(x, lp["ln1"], cfg.norm)
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    attn = _attention(q, k, v, cfg, causal=True,
                      q_positions=positions, kv_positions=positions)
    x = x + attn.reshape(b, s, -1) @ lp["wo"]
    h2 = apply_norm(x, lp["ln2"], cfg.norm)
    y, aux = moe_ffn(lp, h2.reshape(b * s, d), cfg)
    y = y.reshape(b, s, d)
    if cfg.n_shared:
        y = y + (activation(h2 @ lp["ws_gate"], cfg.act)
                 * (h2 @ lp["ws_up"])) @ lp["ws_down"]
    return x + y, aux


def forward(params, tokens: jnp.ndarray, cfg: MoEConfig):
    """tokens (B, S) -> (hidden (B, S, D), mean aux loss)."""
    x = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, lp):
        x, aux = _layer(lp, x, cfg, positions)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxes = jax.lax.scan(body, x, params["layers"])
    return apply_norm(x, params["final_norm"], cfg.norm), jnp.mean(auxes)


def lm_loss(params, tokens, cfg: MoEConfig):
    """Sequence-chunked, rematerialized vocab projection (see transformer)."""
    hidden, aux = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    b, s, d = hidden.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    nc = cfg.loss_chunks if cfg.loss_chunks > 1 and s % cfg.loss_chunks == 0 \
        else 1
    hc = hidden.reshape(b, nc, s // nc, d).swapaxes(0, 1)
    tc = targets.reshape(b, nc, s // nc).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        h, tgt = xs
        logits = (h @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_loss, prevent_cse=False),
                            jnp.float32(0.0), (hc, tc))
    return total / (b * s) + cfg.router_aux_weight * aux


def forward_with_cache(params, tokens: jnp.ndarray, cfg: MoEConfig):
    """Prefill twin of transformer.forward_with_cache (MoE FFN)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)

    def body(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        attn = _attention(q, k, v, cfg, causal=True,
                          q_positions=positions, kv_positions=positions)
        x = x + attn.reshape(b, s, -1) @ lp["wo"]
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        y, _ = moe_ffn(lp, h2.reshape(b * s, -1), cfg)
        y = y.reshape(b, s, -1)
        if cfg.n_shared:
            y = y + (activation(h2 @ lp["ws_gate"], cfg.act)
                     * (h2 @ lp["ws_up"])) @ lp["ws_down"]
        return x + y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x[:, -1, :] @ head, {"k": ks, "v": vs}


# ---------------------------------------------------------------- decode ---
def init_cache(cfg: MoEConfig, batch: int, max_seq: int):
    return transformer.init_cache(cfg, batch, max_seq)


def decode_step(params, cache, tokens, pos, cfg: MoEConfig):
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.jdtype)
    positions = pos[:, None]
    max_seq = cache["k"].shape[2]
    kv_pos = jnp.arange(max_seq)[None, :]

    def update_cache(cache, new, positions_):
        if cfg.scatter_cache_update:
            return jax.vmap(
                lambda c, u, p: jax.lax.dynamic_update_slice(
                    c, u, (p, jnp.int32(0), jnp.int32(0))))(
                cache, new, positions_)
        onehot = (kv_pos == positions_[:, None]).astype(cfg.jdtype)
        return cache + onehot[:, :, None, None] * new

    def body(carry, inp):
        x, = carry
        lp, k_cache, v_cache = inp
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q = (h @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_cache = update_cache(k_cache, k, pos)
        v_cache = update_cache(v_cache, v, pos)
        attn = _attention(q, k_cache, v_cache, cfg, causal=True,
                          q_positions=positions, kv_positions=kv_pos)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"]
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        y, _ = moe_ffn(lp, h2.reshape(b, -1), cfg)
        y = y.reshape(b, 1, -1)
        if cfg.n_shared:
            y = y + (activation(h2 @ lp["ws_gate"], cfg.act)
                     * (h2 @ lp["ws_up"])) @ lp["ws_down"]
        return (x + y,), (k_cache, v_cache)

    (x,), (new_k, new_v) = jax.lax.scan(
        body, (x,), (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x[:, 0, :] @ head, {"k": new_k, "v": new_v}
