"""BackendPolicy: resolution, ExecConfig legacy shims, plan stamping, and
the stable public API surface.

The contract under test: every way of naming a backend configuration — the
policy form, the deprecated per-stage ExecConfig kwargs, or nothing at all —
must resolve to the same concrete `BackendPolicy` and produce bit-identical
query results; and `repro.__all__` is a frozen snapshot that only changes
deliberately.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import repro
from repro import BackendPolicy, ExecConfig, StreakEngine
from repro.core.planner import plan_query
from repro.data import synth_rdf


# ------------------------------------------------------------ resolution ----
def test_resolve_pins_autos_and_is_idempotent():
    p = BackendPolicy().resolve()
    assert p.resolved
    assert p.impl == "merge"            # auto impl -> the two-phase core
    assert p.join == "numpy"            # auto Phase-3 join -> dense numpy
    assert p.kcap == "fixed"
    assert p.resolve() == p             # idempotent


def test_resolve_keeps_explicit_choices():
    p = BackendPolicy(join="fused", impl="looped", rank="interpret",
                      probe="kernel", descend="interpret",
                      kcap="auto").resolve()
    assert p == BackendPolicy(join="fused", impl="looped", rank="interpret",
                              probe="kernel", descend="interpret",
                              kcap="auto")


@pytest.mark.parametrize("field", ["join", "impl", "rank", "probe",
                                   "descend", "kcap"])
def test_resolve_validates_each_stage(field):
    bad = dataclasses.replace(BackendPolicy(), **{field: "no-such-backend"})
    with pytest.raises(ValueError):
        bad.resolve()


# ------------------------------------------------------------ legacy shims --
def test_legacy_knobs_warn_and_fold_into_policy():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ExecConfig(join_backend="fused", join_impl="looped",
                         probe_backend="kernel", rank_backend="interpret",
                         kcap_auto=True)
    msgs = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert msgs and "BackendPolicy" in str(msgs[0].message)
    assert cfg.policy.join == "fused"
    assert cfg.policy.impl == "looped"
    assert cfg.policy.probe == "kernel"
    assert cfg.policy.rank == "interpret"
    assert cfg.policy.kcap == "auto"
    # resolved write-back: legacy readers observe concrete backends
    assert cfg.join_backend == "fused" and cfg.join_impl == "looped"
    assert cfg.probe_backend == "kernel" and cfg.rank_backend == "interpret"
    assert cfg.kcap_auto is True


def test_policy_form_does_not_warn():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ExecConfig(policy=BackendPolicy(join="fused", kcap="auto"))
        default = ExecConfig()
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert cfg.policy.join == "fused" and cfg.kcap_auto is True
    assert default.policy.resolved     # defaults resolve too


def test_legacy_knob_overrides_policy_stage():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = ExecConfig(policy=BackendPolicy(join="kernel"),
                         rank_backend="cpu")
    assert cfg.policy.join == "kernel" and cfg.policy.rank == "cpu"


# --------------------------------------------------------- plan stamping ----
@pytest.fixture(scope="module")
def lgd():
    return synth_rdf.make_lgd(n_per_class=120, seed=3, block=128)


def test_plan_stamps_resolved_backends(lgd):
    plan = plan_query(lgd.store, lgd.queries[0],
                      policy=BackendPolicy(descend="interpret"))
    assert plan.join_impl == "merge"
    assert plan.rank_backend in ("numpy", "kernel")     # resolved, not None
    assert plan.probe_backend in ("numpy", "kernel")
    assert plan.join_backend == "numpy"
    assert plan.descend_backend == "interpret"


# -------------------------------------------- legacy/policy equivalence ----
def test_legacy_and_policy_engines_bit_identical(lgd):
    q = lgd.queries[0]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = StreakEngine(lgd.store, ExecConfig(
            join_backend="fused", join_impl="merge",
            kcap_auto=True, fused_batch_cols=256)).execute(q)
    pol = StreakEngine(lgd.store, ExecConfig(
        policy=BackendPolicy(join="fused", impl="merge", kcap="auto"),
        fused_batch_cols=256)).execute(q)
    np.testing.assert_array_equal(legacy[0], pol[0])
    assert legacy[1].keys() == pol[1].keys()
    for c in pol[1]:
        np.testing.assert_array_equal(legacy[1][c], pol[1][c])


# ------------------------------------------------------------- public API ---
PUBLIC_API = (
    "BackendPolicy", "ExecConfig", "ExecStats", "FaultPlan", "FaultRule",
    "QuadStore", "Query", "QueryDeadline", "Ranking", "Relation",
    "ShardedQuadStore", "SpatialFilter", "StreakEngine", "TriplePattern",
    "Var", "build_store", "shard_store",
)


def test_public_api_snapshot():
    """`repro.__all__` is the stable surface — additions/removals must be
    deliberate (update this snapshot AND the README when they are)."""
    assert tuple(sorted(repro.__all__)) == PUBLIC_API
    for name in PUBLIC_API:
        assert getattr(repro, name) is not None
    from repro import core
    assert tuple(sorted(core.__all__)) == PUBLIC_API
