"""GraphCast-style encoder-processor-decoder mesh GNN [arXiv:2212.12794].

Three stages over two node sets (grid, mesh):
  encode : grid -> mesh along g2m edges (per-edge MLP + sum-aggregate)
  process: `n_layers` of mesh<->mesh interaction-network blocks (edge update
           MLP on [e, src, dst], node update MLP on [node, agg]), residual,
           parameters STACKED and scanned (16 identical blocks)
  decode : mesh -> grid along m2g edges + output head (n_vars)

The grid<->mesh edge sets are built by the STREAK spatial substrate
(core.squadtree.radius_join) in data/graphs.py — the paper's distance join
as graph construction (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers
from .layers import dense_init


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    aggregator: str = "sum"
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        h = self.d_hidden
        enc = self.n_vars * h + 3 * h * h          # embed + g2m edge/node MLPs
        proc = self.n_layers * (3 * h * h + 2 * h * h)
        dec = 3 * h * h + h * self.n_vars
        return enc + proc + dec


def _mlp_init(key, d_in, d_h, d_out, dtype):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (d_in, d_h), dtype=dtype),
            "w2": dense_init(k2, (d_h, d_out), dtype=dtype)}


def _mlp(p, x):
    return jax.nn.silu(x @ p["w1"]) @ p["w2"]


def init_params(key, cfg: GraphCastConfig):
    dt = cfg.jdtype
    h = cfg.d_hidden
    ks = layers.split_keys(key, 10)
    L = cfg.n_layers

    def stack_mlp(k, d_in, d_out):
        k1, k2 = jax.random.split(k)
        return {"w1": dense_init(k1, (L, d_in, h), in_axis=1, dtype=dt),
                "w2": dense_init(k2, (L, h, d_out), in_axis=1, dtype=dt)}

    return {
        "grid_embed": dense_init(ks[0], (cfg.n_vars, h), dtype=dt),
        "g2m_edge": _mlp_init(ks[1], 2 * h, h, h, dt),
        "g2m_node": _mlp_init(ks[2], 2 * h, h, h, dt),
        "proc_edge": stack_mlp(ks[3], 3 * h, h),
        "proc_node": stack_mlp(ks[4], 2 * h, h),
        "m2g_edge": _mlp_init(ks[5], 2 * h, h, h, dt),
        "m2g_node": _mlp_init(ks[6], 2 * h, h, h, dt),
        "out_head": dense_init(ks[7], (h, cfg.n_vars), dtype=dt),
    }


def _bipartite(edge_mlp, node_mlp, src_feats, dst_feats, edges, n_dst,
               aggregator):
    src, dst = edges[0], edges[1]
    e_in = jnp.concatenate([src_feats[src], dst_feats[dst]], axis=-1)
    msg = _mlp(edge_mlp, e_in)
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_dst)
    if aggregator == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((len(src), 1), msg.dtype), dst,
                                  num_segments=n_dst)
        agg = agg / jnp.maximum(cnt, 1.0)
    return _mlp(node_mlp, jnp.concatenate([dst_feats, agg], axis=-1))


def forward(params, grid_x: jnp.ndarray, g2m: jnp.ndarray,
            mesh_edges: jnp.ndarray, m2g: jnp.ndarray, n_mesh: int,
            cfg: GraphCastConfig) -> jnp.ndarray:
    """grid_x (Ng, n_vars); g2m (2, E1) grid->mesh; mesh_edges (2, Em);
    m2g (2, E2) mesh->grid. Returns next-state (Ng, n_vars)."""
    n_grid = grid_x.shape[0]
    g = (grid_x.astype(cfg.jdtype) @ params["grid_embed"])
    m0 = jnp.zeros((n_mesh, cfg.d_hidden), cfg.jdtype)
    m = m0 + _bipartite(params["g2m_edge"], params["g2m_node"], g, m0, g2m,
                        n_mesh, cfg.aggregator)

    src, dst = mesh_edges[0], mesh_edges[1]
    e = jnp.zeros((src.shape[0], cfg.d_hidden), cfg.jdtype)

    def body(carry, lp):
        m, e = carry
        e_in = jnp.concatenate([e, m[src], m[dst]], axis=-1)
        e = e + _mlp(lp["edge"], e_in)
        agg = jax.ops.segment_sum(e, dst, num_segments=n_mesh)
        m = m + _mlp(lp["node"], jnp.concatenate([m, agg], axis=-1))
        return (m, e), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (m, e), _ = jax.lax.scan(
        body, (m, e),
        {"edge": params["proc_edge"], "node": params["proc_node"]})

    g = g + _bipartite(params["m2g_edge"], params["m2g_node"], m, g, m2g,
                       n_grid, cfg.aggregator)
    return (g @ params["out_head"]).astype(jnp.float32)


def mse_loss(params, grid_x, target, g2m, mesh_edges, m2g, n_mesh,
             cfg: GraphCastConfig):
    pred = forward(params, grid_x, g2m, mesh_edges, m2g, n_mesh, cfg)
    return jnp.mean((pred - target.astype(jnp.float32)) ** 2)
