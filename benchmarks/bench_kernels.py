"""Kernel-layer microbenchmarks (jnp reference path on CPU; the Pallas path
is TPU-target and validated in interpret mode by tests)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref

from . import common


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    a = jnp.asarray(rng.random((1024, 4)).astype(np.float32))
    b = jnp.asarray(rng.random((1024, 4)).astype(np.float32))
    f = jax.jit(ref.distance_join_ref)
    f(a, b).block_until_ready()
    t = common.timeit(lambda: f(a, b).block_until_ready())
    rows.append(common.row("kernel/distance_join_1024x1024", t,
                           f"pairs_per_s={1024*1024/(t/1e6):.3e}"))

    # fused streaming top-k join (jnp oracle path on CPU): same tile work
    # plus the per-row top-k fold, HBM output (M, k) instead of (M, N)
    dk = jnp.asarray(rng.random(1024).astype(np.float32))
    vk = jnp.asarray(rng.random(1024).astype(np.float32))
    g2 = jax.jit(lambda a_, b_, dk_, vk_: ref.fused_topk_join_ref(
        a_, b_, dk_, vk_, 0.05, -jnp.inf, 32))
    jax.block_until_ready(g2(a, b, dk, vk))
    t = common.timeit(lambda: jax.block_until_ready(g2(a, b, dk, vk)))
    rows.append(common.row("kernel/fused_topk_join_1024x1024_k32", t,
                           f"pairs_per_s={1024*1024/(t/1e6):.3e}"))

    bits = jnp.asarray(rng.integers(0, 2**32, (8192, 8), dtype=np.uint32))
    lo = jnp.asarray(rng.integers(-2**31, 2**31, 8192, dtype=np.int32))
    hi = jnp.asarray(rng.integers(-2**31, 2**31, 8192, dtype=np.int32))
    g = jax.jit(lambda b_, l, h: ref.bloom_probe_ref(b_, l, h, 3))
    g(bits, lo, hi).block_until_ready()
    t = common.timeit(lambda: g(bits, lo, hi).block_until_ready())
    rows.append(common.row("kernel/bloom_probe_8192", t,
                           f"probes_per_s={8192/(t/1e6):.3e}"))

    scores = jnp.asarray(rng.random((64, 1024)).astype(np.float32))
    h2 = jax.jit(lambda s: ref.block_scan_ref(s, 0.5))
    jax.block_until_ready(h2(scores))
    t = common.timeit(lambda: jax.block_until_ready(h2(scores)))
    rows.append(common.row("kernel/block_scan_64x1024", t, ""))

    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)).astype(np.float32))
    fa = jax.jit(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_))
    fa(q, k, k).block_until_ready()
    t = common.timeit(lambda: fa(q, k, k).block_until_ready())
    flops = 4 * 8 * 512 * 512 * 64
    rows.append(common.row("kernel/attention_gqa_512", t,
                           f"gflops={flops/(t/1e6)/1e9:.1f}"))
    return rows
