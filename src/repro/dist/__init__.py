"""Distribution substrate: elastic resharding + gradient compression.

Companions to repro.launch.mesh — mesh construction lives there, while this
package owns what happens to shardings and gradients when the mesh changes
(device loss, pod folding) or when cross-pod bandwidth is the bottleneck.
"""
from . import elastic, grad_compression  # noqa: F401
