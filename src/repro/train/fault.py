"""Fault tolerance for the training loop.

- `StepGuard`: per-step deadline monitor. A straggling/hung step (common
  failure mode at 1000+ nodes: one slow host stalls the collective) raises
  `StragglerTimeout` so the driver can skip the batch, snapshot, or trigger
  an elastic shrink, instead of hanging the fleet.
- `FailureInjector`: deterministic fault injection for tests (kill at step
  k, slow step, corrupt batch) — the integration tests prove
  checkpoint/restart gives bit-identical resume.
- `run_with_recovery`: restart-on-exception wrapper around a step closure
  with bounded retries and checkpoint-based state restore.

The query-path counterpart is `core/fault.py`: the same ideas — per-call
watchdog (`fault.watchdog` / `OpTimeout` vs `StepGuard` /
`StragglerTimeout`), deterministic injection (`fault.FaultPlan` vs
`FailureInjector`), bounded restart (`SpatialServeEngine`'s fresh-cursor
retries vs `run_with_recovery`) — applied per kernel dispatch and per
served query instead of per training step, plus the pieces that only make
sense there: bit-identical backend failover chains, per-(op, backend)
circuit breakers consulted at plan time, and `QueryDeadline` anytime
results certified by the live θ bound.
"""
from __future__ import annotations

import dataclasses
import threading
import time


class StragglerTimeout(RuntimeError):
    pass


class InjectedFailure(RuntimeError):
    pass


class StepGuard:
    """Watchdog: `with StepGuard(deadline_s): step()` raises on overrun."""

    def __init__(self, deadline_s: float, on_timeout=None):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self._timer: threading.Timer | None = None
        self.fired = False

    def _fire(self):
        self.fired = True
        if self.on_timeout:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.deadline_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        self._timer.cancel()
        if self.fired and exc[0] is None:
            raise StragglerTimeout(
                f"step exceeded {self.deadline_s}s deadline")
        return False


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    slow_at_steps: tuple = ()
    slow_s: float = 0.0
    _failed: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.slow_at_steps:
            time.sleep(self.slow_s)
        if step in self.fail_at_steps and step not in self._failed:
            self._failed.add(step)  # fail once, succeed on retry
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_recovery(step_fn, restore_fn, *, max_restarts: int = 3,
                      on_restart=None):
    """Run `step_fn()` (which loops steps); on exception restore from the
    checkpoint via `restore_fn()` and re-enter, up to max_restarts."""
    restarts = 0
    while True:
        try:
            return step_fn()
        except (InjectedFailure, StragglerTimeout) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
            restore_fn()
