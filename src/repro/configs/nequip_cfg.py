"""nequip [arXiv:2101.03164; paper]: 5L d_hidden(channels)=32 l_max=2
n_rbf=8 cutoff=5, E(3) tensor-product message passing."""
from ..models.equivariant import NequIPConfig
from .registry import GNN_SHAPES as SHAPES  # noqa: F401

FAMILY = "nequip"
CONFIG = NequIPConfig(name="nequip", n_layers=5, n_channels=32, l_max=2,
                      n_rbf=8, cutoff=5.0)
SMOKE = NequIPConfig(name="nequip-smoke", n_layers=2, n_channels=8, l_max=2,
                     n_rbf=4, cutoff=5.0)
