"""gcn-cora [arXiv:1609.02907; paper]: 2L d_hidden=16 mean/sym-norm agg."""
from ..models.gnn import GNNConfig
from .registry import GNN_SHAPES as SHAPES  # noqa: F401

FAMILY = "gnn"
CONFIG = GNNConfig(name="gcn-cora", arch="gcn", n_layers=2, d_in=1433,
                   d_hidden=16, d_out=7, aggregator="mean")
SMOKE = GNNConfig(name="gcn-cora-smoke", arch="gcn", n_layers=2, d_in=32,
                  d_hidden=8, d_out=4, aggregator="mean")
