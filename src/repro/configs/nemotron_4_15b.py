"""nemotron-4-15b [arXiv:2402.16819; unverified]: 32L d_model=6144 48H
(GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU (non-gated) FFN."""
from ..models.transformer import TransformerConfig
from .registry import LM_SHAPES as SHAPES  # noqa: F401

FAMILY = "lm"
CONFIG = TransformerConfig(
    name="nemotron-4-15b", n_layers=32, d_model=6144, n_heads=48,
    n_kv_heads=8, head_dim=128, d_ff=24576, vocab=256000,
    act="sq_relu", glu=False, norm="ln", rope_theta=1e4,
    dtype="bfloat16", remat=True, loss_chunks=16)
SMOKE = TransformerConfig(
    name="nemotron-4-15b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=512, vocab=512,
    act="sq_relu", glu=False, norm="ln", dtype="float32", remat=False)
