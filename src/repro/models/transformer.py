"""Dense decoder-only transformer with GQA, RoPE and pluggable FFN.

Covers nemotron-4-15b (squared-ReLU), codeqwen1.5-7b (SwiGLU),
gemma-7b (GeGLU, head_dim 256). Layer parameters are STACKED with a leading
`n_layers` axis and the forward pass is a `lax.scan`, so HLO size (and
compile time on the 512-device dry-run) is depth-independent.

Sharding contract (logical axes, see dist/partitioning.py):
  embed (V, D):    ("model", None)      - vocab row-shard
  Wq/Wk/Wv:        (None, None,"model") - head column-shard
  Wo:              (None, "model", None) - row-shard
  w_up/w_gate:     (None, None, "model")
  w_down:          (None, "model", None)
Activations: batch -> ("pod","data"), d_model unsharded, heads -> "model".
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import layers
from .layers import activation, apply_norm, dense_init, init_norm, rope


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "transformer"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"          # silu | gelu | sq_relu
    glu: bool = True           # gated FFN (SwiGLU/GeGLU); False = plain MLP
    norm: str = "rms"
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    window: int | None = None  # sliding-window attention (serve-time bound)
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunks: int = 8       # vocab-projection chunking for the LM loss
    use_flash: bool = False    # route attention through the Pallas kernel
    attn_chunk: int | None = None  # query-chunked attention (32k prefill):
    #   bounds the (B,H,chunk,S) logit buffer instead of (B,H,S,S)
    batch_axes: tuple = ()     # residual-stream sharding constraint (SP):
    seq_axes: tuple = ()       #   batch over these axes, seq over these
    attn_bf16_operands: bool = False  # keep QK^T / PV operands in bf16 with
    #   f32 MXU accumulation (halves decode cache read traffic)
    scatter_cache_update: bool = False  # decode: per-slot DUS scatter
    #   instead of one-hot full-cache multiply-add

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        qkv = self.d_model * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
        o = self.n_heads * self.head_dim * self.d_model
        ff = self.d_model * self.d_ff * (3 if self.glu else 2)
        per_layer = qkv + o + ff
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb


def init_params(key, cfg: TransformerConfig):
    dt = cfg.jdtype
    ks = layers.split_keys(key, 8)
    L, D, H, Hk, Dh, F = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                          cfg.n_kv_heads, cfg.head_dim, cfg.d_ff)

    def stack(k, shape):
        return dense_init(k, (L,) + shape, in_axis=1, dtype=dt)

    params = {
        "embed": dense_init(ks[0], (cfg.vocab, D), in_axis=1, dtype=dt),
        "layers": {
            "wq": stack(ks[1], (D, H * Dh)),
            "wk": stack(ks[2], (D, Hk * Dh)),
            "wv": stack(ks[3], (D, Hk * Dh)),
            "wo": stack(ks[4], (H * Dh, D)),
            "w_up": stack(ks[5], (D, F)),
            "w_down": stack(ks[6], (F, D)),
            "ln1": jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                                init_norm(cfg.norm, D)),
            "ln2": jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                                init_norm(cfg.norm, D)),
        },
        "final_norm": init_norm(cfg.norm, D),
    }
    if cfg.glu:
        params["layers"]["w_gate"] = stack(ks[7], (D, F))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[7], (D, cfg.vocab), in_axis=0,
                                       dtype=dt)
    return params


def _attention(q, k, v, cfg: TransformerConfig, causal: bool,
               kv_positions=None, q_positions=None):
    """q (B,S,H,Dh), k/v (B,T,Hk,Dh) -> (B,S,H,Dh). fp32 softmax."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    if cfg.use_flash and s == t and s % 128 == 0:
        from ..kernels import ops as kops
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal)
        return out.transpose(0, 2, 1, 3)
    if cfg.attn_chunk is not None and s > cfg.attn_chunk \
            and s % cfg.attn_chunk == 0:
        if q_positions is None:
            q_positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        ck = cfg.attn_chunk
        nc = s // ck
        qc = q.reshape(b, nc, ck, h, dh).transpose(1, 0, 2, 3, 4)
        qp = q_positions.reshape(b, nc, ck).transpose(1, 0, 2)
        base = dataclasses.replace(cfg, attn_chunk=None)

        def one(args):
            qi, qpi = args
            return _attention(qi, k, v, base, causal,
                              kv_positions=kv_positions, q_positions=qpi)

        out = jax.lax.map(one, (qc, qp))
        return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    qg = q.reshape(b, s, hk, g, dh)
    if cfg.attn_bf16_operands:
        # bf16 reads, f32 accumulation on the MXU: half the HBM traffic for
        # the (large, cache-resident) K/V operands
        logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    else:
        logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * (dh ** -0.5)
    if q_positions is None:
        q_positions = jnp.arange(s)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(t)[None, :]
    mask = kv_positions[:, None, :] <= q_positions[:, :, None]  # (B,S,T)
    if cfg.window is not None:
        mask &= kv_positions[:, None, :] > (q_positions[:, :, None] - cfg.window)
    if not causal:
        mask = jnp.ones_like(mask)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    if cfg.attn_bf16_operands:
        out = jnp.einsum("bhgst,bthd->bshgd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgst,bthd->bshgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


def _ffn(lp, x, cfg: TransformerConfig):
    up = x @ lp["w_up"]
    if cfg.glu:
        up = activation(x @ lp["w_gate"], cfg.act) * up
    else:
        up = activation(up, cfg.act)
    return up @ lp["w_down"]


def _layer(lp, x, cfg: TransformerConfig, positions):
    b, s, d = x.shape
    x = layers.shard_activations(x, cfg.batch_axes, cfg.seq_axes)
    h = apply_norm(x, lp["ln1"], cfg.norm)
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    attn = _attention(q, k, v, cfg, causal=True,
                      q_positions=positions, kv_positions=positions)
    x = x + attn.reshape(b, s, -1) @ lp["wo"]
    x = x + _ffn(lp, apply_norm(x, lp["ln2"], cfg.norm), cfg)
    return x


def forward(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """tokens (B, S) int32 -> final hidden states (B, S, D)."""
    x = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, lp):
        return _layer(lp, x, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(x, params["final_norm"], cfg.norm)


def logits_fn(params, hidden, cfg: TransformerConfig):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return hidden @ head


def lm_loss(params, tokens, cfg: TransformerConfig):
    """Causal LM loss with the vocab projection chunked over the SEQUENCE
    axis (batch stays data-sharded through the reshape) and rematerialized,
    so neither forward nor backward holds more than one (B, sc, V) logit
    chunk."""
    hidden = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    b, s, d = hidden.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    nc = cfg.loss_chunks if cfg.loss_chunks > 1 and s % cfg.loss_chunks == 0 \
        else 1
    hc = hidden.reshape(b, nc, s // nc, d).swapaxes(0, 1)   # (nc, B, sc, D)
    tc = targets.reshape(b, nc, s // nc).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        h, t = xs
        logits = (h @ head).astype(jnp.float32)             # (B, sc, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_loss, prevent_cse=False),
                            jnp.float32(0.0), (hc, tc))
    return total / (b * s)


def forward_with_cache(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Prefill: returns (last-token logits (B, V), kv cache dict).

    Cache layout matches decode_step: (L, B, S, Hkv, Dh).
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)

    def body(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        attn = _attention(q, k, v, cfg, causal=True,
                          q_positions=positions, kv_positions=positions)
        x = x + attn.reshape(b, s, -1) @ lp["wo"]
        x = x + _ffn(lp, apply_norm(x, lp["ln2"], cfg.norm), cfg)
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = logits_fn(params, x[:, -1, :], cfg)
    return logits, {"k": ks, "v": vs}


# ----------------------------------------------------------------- decode ---
def init_cache(cfg: TransformerConfig, batch: int, max_seq: int):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jdtype),
            "v": jnp.zeros(shape, cfg.jdtype)}


def decode_step(params, cache, tokens: jnp.ndarray, pos: jnp.ndarray,
                cfg: TransformerConfig):
    """One-token decode. tokens (B,) int32; pos (B,) current positions.

    Returns (logits (B, V), new_cache). The KV cache sequence axis may be
    sharded (long-context serving): the attention below reduces over the full
    cached axis, which XLA partitions into partial-softmax + all-reduce.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.jdtype)  # (B,1,D)
    positions = pos[:, None]
    max_seq = cache["k"].shape[2]
    kv_pos = jnp.arange(max_seq)[None, :]

    def update_cache(cache, new, positions_):
        if cfg.scatter_cache_update:
            # per-slot scatter (vmapped DUS): touches one row per sequence
            # instead of multiply-adding over the whole cache
            return jax.vmap(
                lambda c, u, p: jax.lax.dynamic_update_slice(
                    c, u, (p, jnp.int32(0), jnp.int32(0))))(
                cache, new, positions_)
        # overwrite, not add: a slot may rewrite a position (e.g. the serve
        # engine steps idle slots during another slot's prefill), and the
        # scatter path below overwrites — the two must stay equivalent
        onehot = (kv_pos == positions_[:, None]).astype(cfg.jdtype)[
            :, :, None, None]
        return cache * (1 - onehot) + onehot * new

    def body(carry, inp):
        x, = carry
        lp, k_cache, v_cache = inp
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q = (h @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_cache = update_cache(k_cache, k, pos)
        v_cache = update_cache(v_cache, v, pos)
        attn = _attention(q, k_cache, v_cache, cfg, causal=True,
                          q_positions=positions, kv_positions=kv_pos)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"]
        x = x + _ffn(lp, apply_norm(x, lp["ln2"], cfg.norm), cfg)
        return (x,), (k_cache, v_cache)

    (x,), (new_k, new_v) = jax.lax.scan(
        body, (x,), (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = logits_fn(params, x[:, 0, :], cfg)
    return logits, {"k": new_k, "v": new_v}
