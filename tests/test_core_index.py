"""Unit + property tests for ids/morton/charsets/squadtree/node_select."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import charsets, geometry, ids, morton, node_select, squadtree


# ---------------------------------------------------------------- morton ----
def test_morton_roundtrip():
    rng = np.random.default_rng(0)
    cx = rng.integers(0, 1 << 20, size=1000)
    cy = rng.integers(0, 1 << 20, size=1000)
    z = morton.interleave2(cx, cy)
    rx, ry = morton.deinterleave2(z)
    np.testing.assert_array_equal(rx.astype(np.int64), cx)
    np.testing.assert_array_equal(ry.astype(np.int64), cy)


def test_morton_locality_prefix():
    # two points in the same level-l cell share the 2l-bit prefix
    xy = np.array([[0.101, 0.202], [0.102, 0.203]])
    z = morton.encode_points(xy, 10)
    lvl = morton.common_level(z[:1], z[1:], 10)
    cells_a = morton.cell_of(xy[:1], int(lvl[0]))
    cells_b = morton.cell_of(xy[1:], int(lvl[0]))
    np.testing.assert_array_equal(cells_a, cells_b)


def test_jnp_morton_matches_numpy():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    xy = rng.random((256, 2))
    for level in (1, 4, 8):
        a = morton.encode_points(xy, level)
        b = np.asarray(morton.jnp_encode_points(jnp.asarray(xy), level))
        np.testing.assert_array_equal(a, b.astype(np.int64))


# ------------------------------------------------------------------- ids ----
@given(st.integers(0, 10), st.integers(0, (1 << 38) - 1), st.data())
@settings(max_examples=200, deadline=None)
def test_id_roundtrip(level, local, data):
    zpath = data.draw(st.integers(0, (1 << (2 * level)) - 1))
    oid = ids.encode(np.int64(zpath), np.int64(level), np.int64(local))
    s, z, l, i = ids.decode(oid)
    assert bool(s) and int(z) == zpath and int(l) == level and int(i) == local
    assert int(oid) > 0  # stays positive


@given(st.integers(1, 10), st.data())
@settings(max_examples=100, deadline=None)
def test_subtree_interval_contains_descendants(level, data):
    zpath = data.draw(st.integers(0, (1 << (2 * level)) - 1))
    lo, hi = ids.subtree_interval(np.int64(zpath), np.int64(level))
    # any descendant id falls inside the interval
    dl = data.draw(st.integers(level, 10))
    suffix = data.draw(st.integers(0, (1 << (2 * (dl - level))) - 1))
    dz = (zpath << (2 * (dl - level))) | suffix
    local = data.draw(st.integers(0, 100))
    did = ids.encode(np.int64(dz), np.int64(dl), np.int64(local))
    assert int(lo) <= int(did) <= int(hi)
    # sibling at same level falls outside
    if (1 << (2 * level)) > 1:
        sib = (zpath + 1) % (1 << (2 * level))
        if sib != zpath:
            sid = ids.encode(np.int64(sib), np.int64(level), np.int64(0))
            assert not (int(lo) <= int(sid) <= int(hi))


def test_nonspatial_ids_have_clear_flag():
    n = ids.nonspatial_ids(10)
    assert not ids.is_spatial(n).any()


# --------------------------------------------------------------- charsets ---
def test_bloom_no_false_negatives():
    bank = charsets.BloomBank.empty(4, words=4, k=3)
    keys = np.arange(100, 150, dtype=np.int64)
    fi = (keys % 4).astype(np.int64)
    bank.add(fi, keys)
    assert bank.contains(fi, keys).all()


def test_bloom_mostly_true_negatives():
    bank = charsets.BloomBank.empty(1, words=32, k=3)
    keys = np.arange(0, 64, dtype=np.int64)
    bank.add(np.zeros(64, np.int64), keys)
    probe = np.arange(10_000, 11_000, dtype=np.int64)
    fp = bank.contains(np.zeros(1000, np.int64), probe).mean()
    assert fp < 0.10


def test_characteristic_sets_group_by_predicates():
    subjects = np.array([1, 1, 2, 2, 3], dtype=np.int64)
    preds = np.array([7, 8, 7, 8, 9], dtype=np.int64)
    uniq, cs = charsets.compute_characteristic_sets(subjects, preds)
    np.testing.assert_array_equal(uniq, [1, 2, 3])
    assert cs[0] == cs[1] and cs[0] != cs[2]


def test_node_cs_stats():
    nodes = np.array([0, 0, 0, 1, 1], dtype=np.int64)
    cs = np.array([5, 5, 6, 5, 7], dtype=np.int64)
    stats = charsets.build_node_cs_stats(nodes, cs, 3)
    assert stats.cardinality(0, np.array([5])) == 2
    assert stats.cardinality(0, np.array([5, 6])) == 3
    assert stats.cardinality(1, np.array([7])) == 1
    assert stats.cardinality(2, np.array([5])) == 0


# -------------------------------------------------------------- squadtree ---
def _toy_tree(n=500, seed=0, leaf_capacity=16, l_max=6):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    sizes = rng.exponential(0.002, size=(n, 2))
    boxes = np.concatenate([pts, pts + sizes], axis=1)
    keys = np.arange(1000, 1000 + n, dtype=np.int64)
    cs = rng.integers(1, 6, size=n).astype(np.int64)
    tree = squadtree.build(keys, boxes, cs, l_max=l_max,
                           leaf_capacity=leaf_capacity)
    return tree, boxes, cs


def test_tree_iranges_are_contiguous_and_nested():
    tree, _, _ = _toy_tree()
    assert np.all(np.diff(tree.obj_ids) > 0)  # unique, sorted ids
    for i in range(tree.n_nodes):
        p = tree.node_parent[i]
        if p >= 0:
            assert tree.irange[p, 0] <= tree.irange[i, 0]
            assert tree.irange[i, 1] <= tree.irange[p, 1]
        sl = tree.subtree_slice(i)
        assert sl.stop - sl.start == tree.n_subtree[i]


def test_tree_subtree_objects_within_cell():
    tree, _, _ = _toy_tree()
    for i in range(tree.n_nodes):
        sl = tree.subtree_slice(i)
        if sl.stop == sl.start:
            continue
        b = tree.obj_mbr[sl]
        cell = tree.node_cell[i]
        eps = 1e-12
        assert (b[:, 0] >= cell[0] - eps).all() and (b[:, 2] <= cell[2] + eps).all()
        assert (b[:, 1] >= cell[1] - eps).all() and (b[:, 3] <= cell[3] + eps).all()


def test_elist_objects_overlap_but_not_contained():
    tree, _, _ = _toy_tree()
    found_any = False
    for i in range(tree.n_nodes):
        el = tree.elist(i)
        if not len(el):
            continue
        found_any = True
        rows = np.searchsorted(tree.obj_ids, el)
        np.testing.assert_array_equal(tree.obj_ids[rows], el)
        cell = tree.node_cell[i]
        b = tree.obj_mbr[rows]
        assert geometry.boxes_intersect(b, cell[None, :]).all()
        # not fully contained: id interval of node must not contain them
        lo, hi = tree.irange[i]
        assert ((el < lo) | (el > hi)).all()
    assert found_any  # exponential sizes guarantee straddlers


def test_candidate_nodes_connected_and_filtering():
    tree, boxes, cs = _toy_tree()
    driver = tree.extent.normalize(boxes[:5])
    in_v = tree.candidate_nodes(driver, 0.01, np.array([cs[0]]))
    assert in_v[0]  # root is in V when V nonempty
    for i in np.flatnonzero(in_v):
        p = tree.node_parent[i]
        if p >= 0:
            assert in_v[p]  # connectivity
    none = tree.candidate_nodes(driver, 0.01, np.array([999999], dtype=np.int64))
    # CS 999999 never inserted -> (near-)certain bloom miss at the root
    assert none.sum() <= in_v.sum()


def test_filter_material_covers_subtree_objects():
    tree, _, _ = _toy_tree()
    in_v = np.ones(tree.n_nodes, dtype=bool)
    v_star = node_select.select(tree, in_v, np.array([1, 2, 3, 4, 5]))
    intervals, explicit = tree.filter_material(v_star)
    covered = np.zeros(tree.n_objects, dtype=bool)
    for lo, hi in intervals:
        a = np.searchsorted(tree.obj_ids, lo, "left")
        b = np.searchsorted(tree.obj_ids, hi, "right")
        covered[a:b] = True
    covered |= np.isin(tree.obj_ids, explicit)
    assert covered.all()


# ------------------------------------------------------------ node_select ---
@pytest.mark.parametrize("seed", range(5))
def test_dp_matches_bruteforce(seed):
    tree, boxes, cs = _toy_tree(n=40, seed=seed, leaf_capacity=4, l_max=3)
    rng = np.random.default_rng(seed)
    in_v = np.zeros(tree.n_nodes, dtype=bool)
    in_v[0] = True
    # connected random V
    for i in range(1, tree.n_nodes):
        if in_v[tree.node_parent[i]] and rng.random() < 0.8:
            in_v[i] = True
    driven = np.array([1, 2], dtype=np.int64)
    params = node_select.SelectParams(alpha_io=1.0, alpha_cpu=0.3, alpha_merge=0.2)
    v_dp = node_select.select(tree, in_v, driven, params)
    v_bf, cost_bf = node_select.brute_force(tree, in_v, driven, params)
    cost_dp, _ = _tree_cost(tree, v_dp, driven, params)
    assert cost_dp <= cost_bf + 1e-9


def _tree_cost(tree, v_star, driven, params):
    cost, xi = node_select.node_costs(
        tree, np.ones(tree.n_nodes, bool), driven, params)
    total = float(cost[v_star].sum())
    with_el = [a for a in v_star if tree.elist_size(int(a)) > 0]
    merge = float(xi[v_star].sum()) if len(with_el) > 1 else 0.0
    return total + merge, merge


def test_select_prefers_cheap_children():
    tree, boxes, cs = _toy_tree(n=200, seed=3, leaf_capacity=8, l_max=4)
    # V restricted to nodes touching a corner region: descending prunes the
    # driven cardinality, so with IO-dominated costs children must win.
    region = np.array([0.0, 0.0, 0.3, 0.3])
    in_v = geometry.boxes_intersect(tree.node_cell, region[None, :])
    in_v[0] = True
    params = node_select.SelectParams(alpha_io=100.0, alpha_cpu=0.0,
                                      alpha_merge=0.0)
    v_star = node_select.select(tree, in_v, np.arange(1, 6), params)
    assert len(v_star) > 1
    assert 0 not in v_star
    # with zero IO cost and huge CPU/merge cost, selecting the root must win
    params2 = node_select.SelectParams(alpha_io=0.0, alpha_cpu=100.0,
                                       alpha_merge=100.0)
    v_root = node_select.select(tree, np.ones(tree.n_nodes, bool),
                                np.arange(1, 6), params2)
    np.testing.assert_array_equal(v_root, [0])


# ------------------------------------------------------------ radius join ---
def test_radius_join_matches_bruteforce():
    rng = np.random.default_rng(7)
    a = rng.random((300, 2)) * 10
    b = rng.random((200, 2)) * 10
    r = 0.7
    i, j = squadtree.radius_join(a, b, r)
    d = np.sqrt(((a[:, None, :] - b[None, :, :]) ** 2).sum(-1))
    exp_i, exp_j = np.nonzero(d <= r)
    got = set(zip(i.tolist(), j.tolist()))
    exp = set(zip(exp_i.tolist(), exp_j.tolist()))
    assert got == exp
