"""APS: Adaptive Processing for Spatial filters (paper §3.3).

Per driver block, estimate the cost of routing the block through

  N-Plan -- driven numeric predicate pushed down: fetch the driven numeric
            index block-wise in score order, early-terminating against the
            shared top-k threshold. Cost grows with `x`, the estimated number
            of driven blocks needed (eq. 3), and pays a per-block random
            access/decompression penalty.
  S-Plan -- spatial join pushed down: one SIP-filtered full scan of the driven
            side; cost grows with C(R), the driven cardinality estimated from
            the spatial characteristic-set statistics at the selected V* nodes.

and route the block through the cheaper one. Because the top-k state is
shared, switching per block costs nothing.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostParams:
    beta_row: float = 1.0      # per-row CPU cost of scan+join work
    beta_seek: float = 32.0    # per-block penalty for N-Plan's repeated
    #                            unsorted accesses (paper §5.2: "overhead of
    #                            retrieving and uncompressing all blocks")
    gamma_join: float = 0.5    # per-candidate spatial-join cost


@dataclasses.dataclass
class PlanDecision:
    plan: str          # "N" or "S"
    cost_n: float
    cost_s: float
    x_blocks: int      # estimated driven blocks before early termination
    c_r: float         # C(R): driven cardinality estimate from V* CS stats
    c_ri: float        # C(R_i) = x * C(R) / nb   (eq. 3 surroundings)


def estimate_c_r(tree, v_star: np.ndarray, driven_cs: np.ndarray,
                 card_all: np.ndarray | None = None) -> float:
    """C(R) from the spatial CS cardinalities stored in the S-QuadTree."""
    if card_all is not None:
        return float(card_all[np.asarray(v_star, dtype=np.int64)].sum())
    total = 0.0
    for a in np.asarray(v_star, dtype=np.int64):
        total += tree.cs_stats.cardinality(int(a), driven_cs)
    return total


def choose(tree, v_star, driven_cs, driven_scan, key_needed: float,
           driver_block_rows: int,
           params: CostParams = CostParams(),
           card_all: np.ndarray | None = None) -> PlanDecision:
    """Route one driver block.

    key_needed: minimum driven score-key that could still produce a top-k
    result given the current threshold and this block's driver keys
    (-inf while the heap is not full -> all blocks needed).
    """
    c_r = estimate_c_r(tree, v_star, driven_cs, card_all)
    if driven_scan is None:
        return PlanDecision("S", np.inf, 0.0, 0, c_r, 0.0)
    nb = max(driven_scan.n_blocks, 1)
    x = driven_scan.blocks_needed(key_needed)
    block_rows = driven_scan.ni.block
    c_ri = x * c_r / nb
    # eq. 3 shape: block-wise (N) pays x * T(R_i) with a per-block random
    # access penalty; full-scan (S) pays T(R) over the SIP-reduced C(R).
    cost_n = x * (params.beta_row * block_rows + params.beta_seek) \
        + params.gamma_join * (c_ri + driver_block_rows)
    cost_s = params.beta_row * c_r \
        + params.gamma_join * (c_r + driver_block_rows)
    plan = "N" if cost_n <= cost_s else "S"
    return PlanDecision(plan, cost_n, cost_s, x, c_r, c_ri)
