"""graphcast [arXiv:2212.12794; unverified]: 16L d_hidden=512
mesh_refinement=6 sum agg, n_vars=227 (encoder-processor-decoder)."""
from ..models.graphcast import GraphCastConfig
from .registry import GNN_SHAPES as SHAPES  # noqa: F401

FAMILY = "graphcast"
CONFIG = GraphCastConfig(name="graphcast", n_layers=16, d_hidden=512,
                         n_vars=227, mesh_refinement=6, aggregator="sum")
SMOKE = GraphCastConfig(name="graphcast-smoke", n_layers=2, d_hidden=32,
                        n_vars=11, mesh_refinement=2, aggregator="sum",
                        dtype="float32", remat=False)
