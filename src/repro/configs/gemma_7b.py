"""gemma-7b [arXiv:2403.08295; hf]: 28L d_model=3072 16H (kv=16)
d_ff=24576, GeGLU, head_dim=256, vocab=256000, tied embeddings."""
from ..models.transformer import TransformerConfig
from .registry import LM_SHAPES as SHAPES  # noqa: F401

FAMILY = "lm"
CONFIG = TransformerConfig(
    name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
    n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
    act="gelu", glu=True, norm="rms", rope_theta=1e4,
    tie_embeddings=True, dtype="bfloat16", remat=True, loss_chunks=16)
SMOKE = TransformerConfig(
    name="gemma-7b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=64, d_ff=512, vocab=512,
    act="gelu", glu=True, norm="rms", tie_embeddings=True,
    dtype="float32", remat=False)
