"""Batched level-synchronous Phases 1-2 vs the looped oracles.

The batched candidate-node frontier (`SQuadTree.candidate_nodes` over a
(B, M, 4) driver-block batch) and the batched node-selection DP
(`node_select.select_batch`) must be *bit-identical* to the per-block python
walks they replaced (`candidate_nodes_looped` / `select_looped`), across
probe backends, and the engine's lookahead-window SIP path must leave
`use_sip=True` results unchanged.
"""
import numpy as np
import pytest

from repro.core import charsets, node_select, squadtree
from repro.core.executor import ExecConfig, StreakEngine
from repro.data import synth_rdf


def _random_tree(rng, n=None, l_max=None, leaf_capacity=None):
    n = n or int(rng.integers(50, 800))
    pts = rng.random((n, 2))
    sizes = rng.exponential(0.004, size=(n, 2))
    boxes = np.concatenate([pts, pts + sizes], axis=1)
    keys = np.arange(1000, 1000 + n, dtype=np.int64)
    cs = rng.integers(1, 8, size=n).astype(np.int64)
    tree = squadtree.build(keys, boxes, cs,
                           l_max=l_max or int(rng.integers(3, 8)),
                           leaf_capacity=leaf_capacity
                           or int(rng.integers(2, 32)))
    return tree, boxes


def _random_batch(rng, tree, boxes, b=None):
    """Ragged batch of driver-block box sets (normalized), incl. empties."""
    box_sets = []
    for _ in range(b or int(rng.integers(1, 6))):
        m = int(rng.integers(0, 20))
        idx = rng.integers(0, len(boxes), size=m)
        box_sets.append(tree.extent.normalize(boxes[idx]) if m
                        else np.zeros((0, 4)))
    return box_sets


# ------------------------------------------------------- level buckets ----
def test_level_buckets_partition_nodes():
    tree, _ = _random_tree(np.random.default_rng(0), n=400)
    seen = np.concatenate([tree.level_nodes(lvl)
                           for lvl in range(tree.n_levels)])
    assert len(seen) == tree.n_nodes
    np.testing.assert_array_equal(np.sort(seen), np.arange(tree.n_nodes))
    for lvl in range(tree.n_levels):
        nodes = tree.level_nodes(lvl)
        np.testing.assert_array_equal(tree.node_level[nodes], lvl)
        # stable bucketing preserves parents-before-children build order
        assert np.all(np.diff(nodes) > 0)


# ----------------------------------------- batched phase 1 + 2 vs loops ----
@pytest.mark.parametrize("seed", range(6))
def test_batched_phases12_bit_identical_to_looped(seed):
    rng = np.random.default_rng(seed)
    tree, boxes = _random_tree(rng)
    box_sets = _random_batch(rng, tree, boxes)
    driven_cs = np.unique(rng.integers(1, 8, size=3).astype(np.int64))
    dist = float(rng.random() * 0.05)
    params = node_select.SelectParams(alpha_io=float(rng.random() * 2),
                                      alpha_cpu=float(rng.random()),
                                      alpha_merge=float(rng.random()))
    masks = tree.candidate_nodes(box_sets, dist, driven_cs)
    assert masks.shape == (len(box_sets), tree.n_nodes)
    v_stars = node_select.select_batch(tree, masks, driven_cs, params)
    for bi, bx in enumerate(box_sets):
        loop_mask = tree.candidate_nodes_looped(bx, dist, driven_cs)
        np.testing.assert_array_equal(masks[bi], loop_mask)
        np.testing.assert_array_equal(
            v_stars[bi], node_select.select_looped(tree, loop_mask,
                                                   driven_cs, params))
        # single-block (M, 4) entry point returns the same (N,) mask
        np.testing.assert_array_equal(
            tree.candidate_nodes(bx, dist, driven_cs), loop_mask)
        np.testing.assert_array_equal(
            node_select.select(tree, loop_mask, driven_cs, params),
            v_stars[bi])


@pytest.mark.parametrize("backend", ["numpy", "kernel", "interpret"])
def test_probe_backends_bit_identical(backend):
    rng = np.random.default_rng(7)
    tree, boxes = _random_tree(rng, n=300)
    box_sets = _random_batch(rng, tree, boxes, b=3)
    driven_cs = np.array([1, 3, 5], dtype=np.int64)
    ref = tree.candidate_nodes(box_sets, 0.02, driven_cs,
                               probe_backend="numpy")
    got = tree.candidate_nodes(box_sets, 0.02, driven_cs,
                               probe_backend=backend)
    np.testing.assert_array_equal(got, ref)


def test_contains_any_batch_matches_contains():
    rng = np.random.default_rng(1)
    bank = charsets.BloomBank.empty(32, words=8, k=3)
    keys = rng.integers(0, 1 << 40, size=200).astype(np.int64)
    bank.add(rng.integers(0, 32, size=200).astype(np.int64), keys)
    probe = np.concatenate([keys[:20], rng.integers(0, 1 << 40, size=20)
                            .astype(np.int64)])
    fi = np.arange(32, dtype=np.int64)
    prep = bank.prepare(probe)
    expect = bank.contains(np.repeat(fi, len(probe)),
                           np.tile(probe, len(fi))
                           ).reshape(len(fi), -1).any(axis=1)
    for backend in ("numpy", "kernel", "interpret"):
        got = bank.contains_any_batch(fi, prep, backend)
        np.testing.assert_array_equal(got, expect)


def test_filter_material_matches_per_node_loop():
    tree, _ = _random_tree(np.random.default_rng(3), n=600, leaf_capacity=4)
    rng = np.random.default_rng(4)
    v_star = np.unique(rng.integers(0, tree.n_nodes, size=12))
    intervals, explicit = tree.filter_material(v_star)
    np.testing.assert_array_equal(intervals, tree.irange[v_star])
    parts = [tree.elist(int(a)) for a in v_star]
    expect = (np.unique(np.concatenate(parts))
              if sum(len(p) for p in parts) else np.empty(0, np.int64))
    np.testing.assert_array_equal(explicit, expect)
    # empty V*
    iv, ex = tree.filter_material(np.empty(0, np.int64))
    assert iv.shape == (0, 2) and len(ex) == 0


# --------------------------------------------- small-tree DP optimality ----
@pytest.mark.parametrize("seed", range(4))
def test_batched_select_optimal_on_small_trees(seed):
    """The batched DP stays optimal: compare against brute_force."""
    rng = np.random.default_rng(seed)
    tree, boxes = _random_tree(rng, n=40, l_max=3, leaf_capacity=4)
    in_v = np.zeros((3, tree.n_nodes), dtype=bool)
    in_v[:, 0] = True
    for b in range(3):
        for i in range(1, tree.n_nodes):
            if in_v[b, tree.node_parent[i]] and rng.random() < 0.8:
                in_v[b, i] = True
    driven = np.array([1, 2], dtype=np.int64)
    params = node_select.SelectParams(alpha_io=1.0, alpha_cpu=0.3,
                                      alpha_merge=0.2)
    v_stars = node_select.select_batch(tree, in_v, driven, params)
    cost, xi = node_select.node_costs(tree, np.ones(tree.n_nodes, bool),
                                      driven, params)
    for b in range(3):
        _, cost_bf = node_select.brute_force(tree, in_v[b], driven, params)
        v_dp = v_stars[b]
        total = float(cost[v_dp].sum())
        with_el = [a for a in v_dp if tree.elist_size(int(a)) > 0]
        total += float(xi[v_dp].sum()) if len(with_el) > 1 else 0.0
        assert total <= cost_bf + 1e-9


# ------------------------------------------------------- engine e2e -------
@pytest.fixture(scope="module")
def lgd():
    return synth_rdf.make_lgd(n_per_class=150, seed=0, block=128)


@pytest.mark.parametrize("qi", range(8))
def test_engine_results_unchanged_under_batched_sip(lgd, qi):
    """use_sip=True results are identical across lookahead widths and to
    the no-SIP exhaustive path (SIP is a pure filter)."""
    q = lgd.queries[qi]
    oracle, _, _ = StreakEngine(lgd.store,
                                ExecConfig(use_sip=False)).execute(q)
    one, _, st1 = StreakEngine(lgd.store,
                               ExecConfig(sip_lookahead=1)).execute(q)
    win, _, stw = StreakEngine(lgd.store,
                               ExecConfig(sip_lookahead=8)).execute(q)
    np.testing.assert_allclose(np.sort(one), np.sort(oracle),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.sort(win), np.sort(one),
                               rtol=1e-9, atol=1e-12)
    # the lookahead window must not change which blocks get SIP-processed
    assert stw.v_star_sizes == st1.v_star_sizes
    assert stw.driver_blocks == st1.driver_blocks


def test_engine_kernel_probe_backend_equivalent(lgd):
    q = lgd.queries[1]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, _ = StreakEngine(
        lgd.store, ExecConfig(probe_backend="kernel")).execute(q)
    np.testing.assert_allclose(np.sort(got), np.sort(ref),
                               rtol=1e-9, atol=1e-12)


# ------------------------------------------------- fused device descent ----
@pytest.mark.parametrize("backend", ["kernel", "interpret"])
def test_descend_backends_bit_identical_to_looped(backend):
    """The fused device descent (tree_descend kernel / interpret mode) must
    reproduce the level-synchronous host frontier — and thus the looped
    oracle — exactly, across ragged batches including empty blocks."""
    for seed in range(4):
        rng = np.random.default_rng(100 + seed)
        tree, boxes = _random_tree(rng)
        box_sets = _random_batch(rng, tree, boxes)
        driven_cs = np.unique(rng.integers(1, 8, size=3).astype(np.int64))
        dist = float(rng.random() * 0.05)
        ref = tree.candidate_nodes(box_sets, dist, driven_cs)
        got = tree.candidate_nodes(box_sets, dist, driven_cs,
                                   descend_backend=backend)
        np.testing.assert_array_equal(got, ref)
        for bi, bx in enumerate(box_sets):
            np.testing.assert_array_equal(
                got[bi], tree.candidate_nodes_looped(bx, dist, driven_cs))


def test_descend_per_block_dist_and_precomputed_cs_path():
    rng = np.random.default_rng(11)
    tree, boxes = _random_tree(rng, n=400)
    box_sets = _random_batch(rng, tree, boxes, b=4)
    driven_cs = np.array([2, 4], dtype=np.int64)
    dists = rng.random(4) * 0.05
    ref = tree.candidate_nodes(box_sets, dists, driven_cs)
    cs_path = tree.cs_path_mask(driven_cs)
    got = tree.candidate_nodes(box_sets, dists, driven_cs,
                               descend_backend="kernel", cs_path=cs_path)
    np.testing.assert_array_equal(got, ref)
    # multi-query form with an aligned per-row cs_path list (serve pooling)
    cs_list = [driven_cs, np.array([1, 5], np.int64), driven_cs,
               np.array([1, 5], np.int64)]
    ref2 = tree.candidate_nodes(box_sets, dists, cs_list)
    paths = [tree.cs_path_mask(c) for c in cs_list]
    got2 = tree.candidate_nodes(box_sets, dists, cs_list,
                                descend_backend="kernel", cs_path=paths)
    np.testing.assert_array_equal(got2, ref2)


def test_cs_path_mask_is_root_path_and_of_bloom_verdicts():
    rng = np.random.default_rng(12)
    tree, _ = _random_tree(rng, n=300)
    driven_cs = np.array([1, 6], dtype=np.int64)
    prep = tree.bloom_self.prepare(driven_cs)
    node_hit = tree.bloom_self.contains_any_batch(
        np.arange(tree.n_nodes, dtype=np.int64), prep, "numpy")
    path = tree.cs_path_mask(driven_cs)
    for n in range(tree.n_nodes):
        expect, a = True, n
        while True:
            expect &= bool(node_hit[a])
            if a == 0:
                break
            a = int(tree.node_parent[a])
        assert path[n] == expect, n


def test_engine_descend_backend_equivalent(lgd):
    from repro import BackendPolicy
    q = lgd.queries[2]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, _ = StreakEngine(lgd.store, ExecConfig(
        policy=BackendPolicy(descend="kernel"))).execute(q)
    np.testing.assert_allclose(np.sort(got), np.sort(ref),
                               rtol=1e-9, atol=1e-12)
