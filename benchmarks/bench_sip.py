"""Fig. 7 + Phases 1-2: sideways information passing, looped vs batched.

Two parts:

- ``fig7_sip/`` — per benchmark query: execution time with SIP on vs off
  (fixed S-Plan so the only difference is the I-Range/E-list filtering),
  plus driven rows scanned. Expected pattern (paper §5.1.1): large wins on
  spatially selective queries, little effect on low-selectivity ones.
- ``sip_phase/`` — phase-level timings of the Phase 1-2 serial prefix on a
  ≥10k-node synthetic tree: the per-block python walks
  (``candidate_nodes_looped`` + ``select_looped``) against the batched
  level-synchronous pipeline (``candidate_nodes`` over a driver-block batch
  + ``select_batch``). The acceptance target is ≥ 5x on the combined
  Phase 1-2 time in the spatially-selective regime.
"""
from __future__ import annotations

import numpy as np

from repro import ExecConfig, StreakEngine
from repro.core import node_select, squadtree

from . import common

# phase-benchmark workloads: (name, n_blocks, boxes_per_block, dist, n_cs)
_PHASE_CASES = [
    ("selective", 16, 64, 0.003, 1),
    ("wide", 16, 64, 0.01, 5),
]


def _phase_tree(n=40_000, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    sizes = rng.exponential(0.0015, size=(n, 2))
    boxes = np.concatenate([pts, pts + sizes], axis=1)
    tree = squadtree.build(np.arange(n, dtype=np.int64) + 10, boxes,
                           rng.integers(1, 12, size=n).astype(np.int64),
                           l_max=9, leaf_capacity=4)
    return tree, boxes, rng


def _phase_rows() -> list:
    tree, boxes, rng = _phase_tree()
    assert tree.n_nodes >= 10_000
    rows = []
    params = node_select.SelectParams()
    for name, n_blocks, m, dist, n_cs in _PHASE_CASES:
        box_sets = [tree.extent.normalize(
            boxes[rng.integers(0, len(boxes), size=m)])
            for _ in range(n_blocks)]
        driven_cs = np.arange(1, 1 + n_cs, dtype=np.int64)
        card = tree.cs_stats.cardinality_all(driven_cs)
        prep = tree.bloom_self.prepare(driven_cs)

        def p1_loop():
            return [tree.candidate_nodes_looped(b, dist, driven_cs)
                    for b in box_sets]

        def p1_batch():
            return tree.candidate_nodes(box_sets, dist, driven_cs,
                                        prepared=prep)

        masks_l, masks_b = p1_loop(), p1_batch()
        for mask_b, mask_l in zip(masks_b, masks_l):
            np.testing.assert_array_equal(mask_b, mask_l)

        def p2_loop():
            return [node_select.select_looped(tree, mk, driven_cs, params,
                                              card) for mk in masks_l]

        def p2_batch():
            return node_select.select_batch(tree, masks_b, driven_cs,
                                            params, card)

        for v_b, v_l in zip(p2_batch(), p2_loop()):
            np.testing.assert_array_equal(v_b, v_l)

        def p12_loop():
            return [node_select.select_looped(tree, mk, driven_cs, params,
                                              card) for mk in p1_loop()]

        def p12_batch():
            return node_select.select_batch(tree, p1_batch(), driven_cs,
                                            params, card)

        shape = (f"nodes={tree.n_nodes};blocks={n_blocks};m={m};"
                 f"dist={dist};cs={n_cs}")
        t1l, t1b = common.timeit(p1_loop), common.timeit(p1_batch)
        t2l, t2b = common.timeit(p2_loop), common.timeit(p2_batch)
        tl, tb = common.timeit(p12_loop), common.timeit(p12_batch)
        rows += [
            common.row(f"sip_phase/{name}/phase1_looped", t1l, shape),
            common.row(f"sip_phase/{name}/phase1_batched", t1b,
                       f"speedup={t1l/max(t1b,1):.2f}x"),
            common.row(f"sip_phase/{name}/phase2_looped", t2l, shape),
            common.row(f"sip_phase/{name}/phase2_batched", t2b,
                       f"speedup={t2l/max(t2b,1):.2f}x"),
            common.row(f"sip_phase/{name}/phase12_looped", tl, shape),
            common.row(f"sip_phase/{name}/phase12_batched", tb,
                       f"speedup={tl/max(tb,1):.2f}x"),
        ]
    return rows


def _descend_rows() -> list:
    """Phase-1 traversal routes: the batched level-synchronous host
    frontier vs the fused descent (`descend_backend="kernel"`, which on CPU
    runs the jitted dense collapse — zero per-level host round-trips; on
    TPU the same dispatch runs the Pallas tree_descend kernel). The
    root-path Bloom mask is precomputed once per query (`cs_path_mask`),
    exactly as the executor's cursor does."""
    tree, boxes, rng = _phase_tree()
    rows = []
    for name, n_blocks, m, dist, n_cs in _PHASE_CASES:
        box_sets = [tree.extent.normalize(
            boxes[rng.integers(0, len(boxes), size=m)])
            for _ in range(n_blocks)]
        driven_cs = np.arange(1, 1 + n_cs, dtype=np.int64)
        prep = tree.bloom_self.prepare(driven_cs)
        cs_path = tree.cs_path_mask(driven_cs, prepared=prep)

        def frontier():
            return tree.candidate_nodes(box_sets, dist, driven_cs,
                                        prepared=prep)

        def fused():
            return tree.candidate_nodes(box_sets, dist, driven_cs,
                                        prepared=prep,
                                        descend_backend="kernel",
                                        cs_path=cs_path)

        np.testing.assert_array_equal(fused(), frontier())
        tf, td = common.timeit(frontier), common.timeit(fused)
        shape = (f"nodes={tree.n_nodes};blocks={n_blocks};m={m};"
                 f"dist={dist};cs={n_cs}")
        rows += [
            common.row(f"sip_descend/{name}/frontier", tf, shape),
            common.row(f"sip_descend/{name}/fused", td,
                       f"speedup={tf/max(td,1):.2f}x"),
        ]
    return rows


def run() -> list:
    rows = _phase_rows() + _descend_rows()
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            eng_on = StreakEngine(ds.store, ExecConfig(force_plan="S"))
            eng_off = StreakEngine(ds.store,
                                   ExecConfig(force_plan="S", use_sip=False))
            t_on = common.timeit(lambda: eng_on.execute(q))
            t_off = common.timeit(lambda: eng_off.execute(q))
            _, _, s_on = eng_on.execute(q)
            _, _, s_off = eng_off.execute(q)
            rows.append(common.row(
                f"fig7_sip/{ds_name}/Q{qi+1}_on", t_on,
                f"join_rows={s_on.driven_rows_after_sip};"
                f"pairs={s_on.join.pairs_tested}"))
            rows.append(common.row(
                f"fig7_sip/{ds_name}/Q{qi+1}_off", t_off,
                f"join_rows={s_off.driven_rows_after_sip};"
                f"pairs={s_off.join.pairs_tested};"
                f"speedup={t_off/max(t_on,1):.2f}x"))
    return rows
