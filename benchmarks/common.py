"""Shared benchmark utilities: datasets, timing, CSV rows."""
from __future__ import annotations

import time

from repro.data import synth_rdf

_CACHE: dict = {}


def dataset(name: str):
    """Benchmark-scale synthetic datasets (cached per process).

    Sized so the driven-side scans dominate the per-block overheads (the
    regime the paper evaluates: LGD/YAGO3 are 30M-85M quads, disk-bound; at
    toy scale SIP's pruning cannot amortize its Phase-1/2 cost).
    """
    if name not in _CACHE:
        if name == "lgd":
            _CACHE[name] = synth_rdf.make_lgd(n_per_class=6000, seed=0,
                                              block=1024)
        else:
            _CACHE[name] = synth_rdf.make_yago(n_places=20000, seed=1,
                                               block=1024)
    return _CACHE[name]


def timeit(fn, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall-time in microseconds (paper protocol: repeated runs,
    average of the final ones)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
