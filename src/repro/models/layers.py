"""Shared model building blocks: norms, rotary embedding, activations, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sq_relu":      # squared ReLU (Primer; Nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind}")


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4):
    """Rotary embedding. x (..., S, H, Dh); positions (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def shard_activations(x: jnp.ndarray, batch_axes: tuple, seq_axes: tuple):
    """Megatron-SP style residual-stream constraint: (B, S, D) with batch
    over the data axes and sequence over "model". The remat-saved per-layer
    carry shrinks by the model-axis factor; XLA inserts the all-gather /
    reduce-scatter pair at the TP boundary."""
    if not batch_axes and not seq_axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(batch_axes) if batch_axes else None,
             tuple(seq_axes) if seq_axes else None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
