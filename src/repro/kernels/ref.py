"""Pure-jnp oracles for every Pallas kernel.

Each `*_ref` is the semantic specification; kernel tests sweep shapes/dtypes
and assert_allclose kernels (interpret=True on CPU) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- distance join --
def distance_join_ref(driver: jnp.ndarray, driven: jnp.ndarray) -> jnp.ndarray:
    """Pairwise min distance between boxes. driver (M,4), driven (N,4) ->
    (M, N) float32 (0 where boxes intersect)."""
    a = driver[:, None, :]
    b = driven[None, :, :]
    dx = jnp.maximum(0.0, jnp.maximum(a[..., 0] - b[..., 2],
                                      b[..., 0] - a[..., 2]))
    dy = jnp.maximum(0.0, jnp.maximum(a[..., 1] - b[..., 3],
                                      b[..., 1] - a[..., 3]))
    return jnp.sqrt(dx * dx + dy * dy).astype(jnp.float32)


# ------------------------------------------------- fused top-k distance join --
def fused_topk_join_ref(driver: jnp.ndarray, driven: jnp.ndarray,
                        driver_keys: jnp.ndarray, driven_keys: jnp.ndarray,
                        dist, theta, k: int,
                        row_qid: jnp.ndarray | None = None,
                        col_qid: jnp.ndarray | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense oracle for kernels/fused_topk_join.py.

    Materializes the (M, N) distance matrix (it is the *specification*, not
    the streaming implementation) and reduces it to the same (M, k) per-row
    partials: pair survives iff box_dist <= dist AND key bound
    driver_keys[i] + driven_keys[j] > theta AND (when query ids are given)
    both rows belong to the same query. `dist` / `theta` may be scalars or
    per-driver-row (M,) arrays. Returns (scores (M, k), idx (M, k) int32,
    counts (M,) int32) padded with -inf / -1.
    """
    d = distance_join_ref(driver, driven)
    m = d.shape[0]
    bound = (driver_keys.astype(jnp.float32)[:, None]
             + driven_keys.astype(jnp.float32)[None, :])
    dist_row = jnp.broadcast_to(jnp.asarray(dist, jnp.float32), (m,))
    theta_row = jnp.broadcast_to(jnp.asarray(theta, jnp.float32), (m,))
    valid = (d <= dist_row[:, None]) & (bound > theta_row[:, None])
    if row_qid is not None and col_qid is not None:
        valid &= (row_qid.astype(jnp.int32)[:, None]
                  == col_qid.astype(jnp.int32)[None, :])
    m, n = d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    s = jnp.where(valid, bound, -jnp.inf)
    i = jnp.where(valid, col, -1)
    kk = min(k, n)
    top_s, pos = jax.lax.top_k(s, kk)
    top_i = jnp.take_along_axis(i, pos, axis=1)
    top_i = jnp.where(jnp.isneginf(top_s), -1, top_i)
    if kk < k:  # fewer candidates than the partial width: pad
        top_s = jnp.pad(top_s, ((0, 0), (0, k - kk)),
                        constant_values=-jnp.inf)
        top_i = jnp.pad(top_i, ((0, 0), (0, k - kk)), constant_values=-1)
    counts = jnp.sum(valid.astype(jnp.int32), axis=1)
    return top_s, top_i, counts


# ------------------------------------------- bucketed geometry refinement --
def bucketed_min_core_ref(a_planes: tuple, b_planes: tuple) -> jnp.ndarray:
    """Oracle for kernels/geom_refine.py: per-row min squared distance.

    a_planes / b_planes: dims-tuples of (B, m_pad) / (B, n_pad) float32
    coordinate planes; padding must replicate real points of the same
    entity. Returns (B,) float32 minima of ``sum_d (a_d - b_d)²`` over each
    row's point pairs — the metric *core* (squared euclid for dims=2; the
    unit-sphere chord², i.e. 4·haversine-h, for dims=3). The core is
    monotone in the true distance, so the caller applies the final transform
    (sqrt; 2R·asin(√/2)) once per pair in float64 numpy — XLA's jitted
    ``asin`` is not exact at 0, which would turn self-distances into
    ~3e-4 km.
    """
    core = None
    for ad, bd in zip(a_planes, b_planes):
        d = ad[:, :, None] - bd[:, None, :]
        core = d * d if core is None else core + d * d
    return jnp.min(core, axis=(1, 2))


# --------------------------------------------------- merge-join rank pass --
def merge_join_ranks_ref(t_hi: jnp.ndarray, t_lo: jnp.ndarray,
                         p_hi: jnp.ndarray, p_lo: jnp.ndarray
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels/merge_join.py: dense counting insertion ranks.

    t_* (N,) / p_* (M,) int32 planes of int64 keys split as
    (hi32, sign-bit-flipped lo32), table sorted by the underlying int64.
    Materializes the (M, N) comparison masks (it is the specification, not
    the streaming implementation) and returns (lo (M,), hi (M,)) int32 with
    lo[i] = #{table < probe_i}, hi[i] = #{table <= probe_i}.
    """
    hi_eq = t_hi[None, :] == p_hi[:, None]
    lt = (t_hi[None, :] < p_hi[:, None]) | (hi_eq
                                            & (t_lo[None, :] < p_lo[:, None]))
    le = lt | (hi_eq & (t_lo[None, :] == p_lo[:, None]))
    return (jnp.sum(lt.astype(jnp.int32), axis=1),
            jnp.sum(le.astype(jnp.int32), axis=1))


# ------------------------------------------------------------ tree descent --
def tree_descend_ref(nodes_hi: jnp.ndarray, nodes_lo: jnp.ndarray,
                     cs: jnp.ndarray, boxes_hi: jnp.ndarray,
                     boxes_lo: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels/tree_descend.py: dense candidate-node masks.

    nodes_* (4, N) int32 key planes of node MBRs (rows x0, y0, x2, y3);
    cs (N,) int32 0/1 root-path Bloom mask; boxes_* (B, M, 4) planes of
    expanded driver boxes. Materializes the (B, M, N) interval tests (the
    specification, not the tiled implementation) and returns (B, N) int32:
    any box intersecting the node MBR, masked by cs.
    """
    def le(a_hi, a_lo, b_hi, b_lo):
        return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))

    def node(c):
        return nodes_hi[c][None, None, :], nodes_lo[c][None, None, :]

    def box(c):
        return boxes_hi[:, :, c][:, :, None], boxes_lo[:, :, c][:, :, None]

    hit = (le(*node(0), *box(2)) & le(*box(0), *node(2))
           & le(*node(1), *box(3)) & le(*box(1), *node(3)))
    any_hit = jnp.max(hit.astype(jnp.int32), axis=1)        # (B, N)
    return any_hit & cs.astype(jnp.int32)[None, :]


# -------------------------------------------------------------- bloom probe --
def _mix32_jnp(x, seed: int):
    x = (x + jnp.uint32(0x9E3779B9) * jnp.uint32(seed + 1)).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = (x * jnp.uint32(0x85EBCA6B)).astype(jnp.uint32)
    x = x ^ (x >> 13)
    x = (x * jnp.uint32(0xC2B2AE35)).astype(jnp.uint32)
    x = x ^ (x >> 16)
    return x


def hash32_jnp(lo: jnp.ndarray, hi: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Matches repro.core.charsets.hash32 given the key's (lo32, hi32)."""
    return _mix32_jnp(lo.astype(jnp.uint32) ^ _mix32_jnp(hi.astype(jnp.uint32),
                                                         seed + 7), seed)


def bloom_probe_ref(bits: jnp.ndarray, key_lo: jnp.ndarray,
                    key_hi: jnp.ndarray, k: int) -> jnp.ndarray:
    """bits (B, W) uint32 (pre-gathered filter rows), keys split into 32-bit
    halves. Returns (B,) bool: all k probe bits set."""
    nbits = bits.shape[1] * 32
    h1 = hash32_jnp(key_lo, key_hi, 0)
    h2 = hash32_jnp(key_lo, key_hi, 1) | jnp.uint32(1)
    hit = jnp.ones(bits.shape[0], dtype=bool)
    for i in range(k):
        pos = (h1 + jnp.uint32(i) * h2) % jnp.uint32(nbits)
        w = (pos // 32).astype(jnp.int32)
        bshift = (pos % 32).astype(jnp.uint32)
        # one-hot word select (kernel does the same trick: no in-row gather)
        sel = jnp.sum(
            bits * (jax.lax.broadcasted_iota(jnp.int32, bits.shape, 1)
                    == w[:, None]).astype(jnp.uint32), axis=1)
        hit &= ((sel >> bshift) & jnp.uint32(1)) == 1
    return hit


# ---------------------------------------------------------------- block scan --
def block_scan_ref(scores: jnp.ndarray, theta: float
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Blocked top-k summary pass. scores (nb, B) float32.

    Returns (block_max (nb,), survivor_count (nb,), mask (nb, B) uint8) where
    survivors are entries with score > theta.
    """
    mask = scores > theta
    return (scores.max(axis=1),
            mask.sum(axis=1).astype(jnp.int32),
            mask.astype(jnp.uint8))


# ------------------------------------------------------------------- morton --
def morton_ref(cx: jnp.ndarray, cy: jnp.ndarray) -> jnp.ndarray:
    """Interleave 16-bit cell coords -> int32 Morton code. Any shape."""
    def spread(v):
        v = v.astype(jnp.uint32) & jnp.uint32(0xFFFF)
        v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
        v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
        v = (v | (v << 2)) & jnp.uint32(0x33333333)
        v = (v | (v << 1)) & jnp.uint32(0x55555555)
        return v
    return (spread(cx) | (spread(cy) << 1)).astype(jnp.int32)


# --------------------------------------------------------- flash attention --
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, scale: float | None = None
                        ) -> jnp.ndarray:
    """GQA attention oracle. q (B, Hq, S, D); k, v (B, Hkv, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32)
                      ).astype(q.dtype)
