"""Quad store with exhaustive permutation indexes + numeric block summaries.

Follows RDF-3X / Quark-X (paper §3): quads ``(g, s, p, o)`` where ``g`` is the
reification (fact) id, stored under multiple sort orders so that any bound
prefix becomes a binary-search range scan. A per-predicate *numeric index*
keeps facts sorted by the literal value with per-block min/max summaries —
the substrate for top-k early termination and for the APS `x`-block estimate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import charsets, geometry
from .dictionary import Dictionary
from .squadtree import SQuadTree, build as build_tree, csr_gather

# column order names -> tuple of column indices into (g, s, p, o)
G, S, P, O = 0, 1, 2, 3
ORDERS = {
    "spog": (S, P, O, G), "posg": (P, O, S, G), "ospg": (O, S, P, G),
    "psog": (P, S, O, G), "opsg": (O, P, S, G), "sopg": (S, O, P, G),
    "gspo": (G, S, P, O), "pogs": (P, O, G, S),
}
DEFAULT_BLOCK = 1024


@dataclasses.dataclass
class NumericIndex:
    """Facts of one predicate sorted by numeric object value (descending)."""

    values: np.ndarray     # (m,) float64, sorted desc
    subjects: np.ndarray   # (m,) int64
    objects: np.ndarray    # (m,) int64 literal ids
    facts: np.ndarray      # (m,) int64 (g column)
    block: int
    block_max: np.ndarray  # (nb,) upper bound per block (= first value)
    block_min: np.ndarray  # (nb,) lower bound per block

    @property
    def n_blocks(self) -> int:
        return len(self.block_max)

    @property
    def n_rows(self) -> int:
        return len(self.values)

    def get_block(self, b: int) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                         np.ndarray]:
        sl = slice(b * self.block, min((b + 1) * self.block, len(self.values)))
        return self.values[sl], self.subjects[sl], self.objects[sl], self.facts[sl]


class DirectedNumericScan:
    """Score-key-ordered block view of a NumericIndex.

    key(v) = v for descending ranking, -v for ascending; block 0 always holds
    the best keys so the top-k threshold logic is direction-agnostic.
    """

    def __init__(self, ni: NumericIndex, descending: bool):
        self.ni = ni
        self.descending = descending

    @property
    def n_blocks(self) -> int:
        return self.ni.n_blocks

    @property
    def n_rows(self) -> int:
        return self.ni.n_rows

    def best_key(self, b: int) -> float:
        if self.descending:
            return float(self.ni.block_max[b])
        return float(-self.ni.block_min[self.ni.n_blocks - 1 - b])

    def global_best(self) -> float:
        return self.best_key(0) if self.n_blocks else -np.inf

    def global_worst(self) -> float:
        if not self.n_blocks:
            return -np.inf
        last = self.n_blocks - 1
        if self.descending:
            return float(self.ni.block_min[last])
        return float(-self.ni.block_max[0])

    def get_block(self, b: int):
        bb = b if self.descending else self.ni.n_blocks - 1 - b
        v, s, o, f = self.ni.get_block(bb)
        if not self.descending:
            v, s, o, f = v[::-1], s[::-1], o[::-1], f[::-1]
        return v, s, o, f

    def blocks_needed(self, key_threshold: float) -> int:
        """How many leading blocks can still contain keys > threshold --
        the paper's estimate `x` of blocks fetched before early termination."""
        if not np.isfinite(key_threshold):
            return self.n_blocks
        count = 0
        for b in range(self.n_blocks):
            if self.best_key(b) > key_threshold:
                count += 1
            else:
                break
        return count


def _sorted_lut(d: dict) -> tuple[np.ndarray, np.ndarray]:
    """dict -> (sorted int64 keys, aligned int64 values) for vector lookup."""
    if not d:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    keys = np.fromiter(d.keys(), np.int64, len(d))
    vals = np.fromiter(d.values(), np.int64, len(d))
    order = np.argsort(keys)
    return keys[order], vals[order]


def lut_get(keys: np.ndarray, vals: np.ndarray, col: np.ndarray,
            default: int = 0) -> np.ndarray:
    """Vectorized ``{k: v}.get(x, default)`` over a sorted-key LUT."""
    col = np.asarray(col, dtype=np.int64)
    out = np.full(len(col), default, dtype=np.int64)
    if len(keys):
        pos = np.clip(np.searchsorted(keys, col), 0, len(keys) - 1)
        hit = keys[pos] == col
        out[hit] = vals[pos[hit]]
    return out


def _segmented_unique_csr(seg: np.ndarray, vals: np.ndarray, n_seg: int
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Per-segment sorted-unique values -> CSR ``(offsets, values)``.

    Matches ``[np.unique(vals[seg == i]) for i in range(n_seg)]`` exactly
    (sorted unique per segment, concatenated) without the python loop.
    """
    if len(seg) == 0:
        return np.zeros(n_seg + 1, dtype=np.int64), np.empty(0, np.int64)
    order = np.lexsort((vals, seg))
    s_s, v_s = seg[order], vals[order]
    keep = np.empty(len(s_s), dtype=bool)
    keep[0] = True
    keep[1:] = (s_s[1:] != s_s[:-1]) | (v_s[1:] != v_s[:-1])
    s_u, v_u = s_s[keep], v_s[keep]
    off = np.zeros(n_seg + 1, dtype=np.int64)
    np.add.at(off, s_u + 1, 1)
    return np.cumsum(off), v_u


def _entity_cs_csr(quads: np.ndarray, ent: np.ndarray,
                   cs_keys: np.ndarray, cs_vals: np.ndarray
                   ) -> tuple[tuple[np.ndarray, np.ndarray],
                              tuple[np.ndarray, np.ndarray]]:
    """Per-entity incoming/outgoing characteristic-set CSRs.

    incoming(e) = unique CS of subjects s with a quad (s, p, e);
    outgoing(e) = unique CS of objects o of quads (e, p, o). One sort per
    direction + a segmented unique — the vectorized twin of the original
    per-entity loop (identical CSRs), shared by `build_store` and the
    shard builder (`core/shard.py`), where `ent` holds spatial ids against
    the remapped quads (the remap is bijective, so the sets agree with
    build time).
    """
    ent = np.asarray(ent, dtype=np.int64)

    def one(col_sort: int, col_take: int):
        order = np.argsort(quads[:, col_sort], kind="stable")
        sorted_col = quads[order, col_sort]
        lo = np.searchsorted(sorted_col, ent, "left")
        hi = np.searchsorted(sorted_col, ent, "right")
        cnt = hi - lo
        rows = order[csr_gather(lo, cnt)]
        seg = np.repeat(np.arange(len(ent), dtype=np.int64), cnt)
        cs = lut_get(cs_keys, cs_vals, quads[rows, col_take])
        return _segmented_unique_csr(seg, cs, len(ent))

    return one(O, S), one(S, O)


def _build_numeric_index(values, subjects, objects, facts, block: int
                         ) -> NumericIndex:
    order = np.argsort(-values, kind="stable")
    v, s, o, f = values[order], subjects[order], objects[order], facts[order]
    nb = (len(v) + block - 1) // block
    bmax = np.array([v[i * block] for i in range(nb)]) if nb else np.empty(0)
    bmin = np.array([v[min((i + 1) * block, len(v)) - 1] for i in range(nb)]) \
        if nb else np.empty(0)
    return NumericIndex(v, s, o, f, block, bmax, bmin)


@dataclasses.dataclass
class GeomPool:
    """CSR pool of exact point-set geometries (paper §3.2.4 refinement).

    One flat ``(P, 2)`` float32 point array plus ``(E+1,)`` offsets: pool row
    ``r`` owns ``points[offsets[r] : offsets[r+1]]``. Rows ``0..n_entities-1``
    follow ``tree.obj_ids`` order (exact geometry when ingested, denormalized
    MBR corners otherwise) and the final row is a single-point ``(0, 0)``
    sentinel for unknown entities — every row holds >= 1 point, so dense
    gathers can pad by replicating a real point instead of masking.
    """

    points: np.ndarray    # (P, 2) float32
    offsets: np.ndarray   # (E+1,) int64, offsets[0] == 0
    # cached contiguous coordinate planes (see planes2d / planes3d)
    _p2d: tuple | None = dataclasses.field(default=None, init=False,
                                           repr=False, compare=False)
    _p3d: tuple | None = dataclasses.field(default=None, init=False,
                                           repr=False, compare=False)

    @classmethod
    def empty(cls) -> "GeomPool":
        return cls.from_lists([])

    @classmethod
    def from_lists(cls, geoms: list) -> "GeomPool":
        """Pack per-entity (m, 2) point arrays into CSR (one pool row per
        entry, in order) and append the sentinel row — the one authoritative
        encoder of the pool layout."""
        pts = [np.asarray(g, dtype=np.float32).reshape(-1, 2) for g in geoms]
        pts.append(np.zeros((1, 2), dtype=np.float32))      # sentinel
        offsets = np.zeros(len(pts) + 1, dtype=np.int64)
        offsets[1:] = np.cumsum([len(p) for p in pts])
        return cls(np.concatenate(pts, axis=0), offsets)

    @property
    def n_entities(self) -> int:
        """Pool rows backed by real entities (the sentinel row excluded)."""
        return len(self.offsets) - 2

    @property
    def sentinel_row(self) -> int:
        return self.n_entities

    def counts(self, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        return self.offsets[rows + 1] - self.offsets[rows]

    def planes2d(self) -> tuple:
        """Contiguous (P,) float32 x / y planes for euclidean refinement."""
        if self._p2d is None:
            self._p2d = (np.ascontiguousarray(self.points[:, 0]),
                         np.ascontiguousarray(self.points[:, 1]))
        return self._p2d

    def planes3d(self) -> tuple:
        """Contiguous (P,) float32 unit-sphere X / Y / Z planes.

        Points are (lon, lat) degrees; the chord length between unit vectors
        relates to the haversine term by ``chord² = 4h``, so great-circle
        refinement reduces to a squared euclidean distance in R³ — the
        per-point trig happens once here instead of once per candidate pair
        inside the kernel (computed in f64, stored f32).
        """
        if self._p3d is None:
            lon = np.radians(self.points[:, 0].astype(np.float64))
            lat = np.radians(self.points[:, 1].astype(np.float64))
            cl = np.cos(lat)
            self._p3d = ((cl * np.cos(lon)).astype(np.float32),
                         (cl * np.sin(lon)).astype(np.float32),
                         np.sin(lat).astype(np.float32))
        return self._p3d

    def nbytes(self) -> int:
        return self.points.nbytes + self.offsets.nbytes


def _build_geom_pool(tree: SQuadTree | None, exact_geoms: dict) -> GeomPool:
    """Per-entity geometries in tree.obj_ids order, MBR-corner fallback."""
    if tree is None:
        return GeomPool.from_lists([])
    ext = tree.extent
    if not exact_geoms:
        # all-MBR fast path (the synthetic scaling datasets): two corner
        # points per entity, built dense — bit-identical to the loop (f64
        # denormalize, then the f32 cast `from_lists` would apply)
        m = len(tree.obj_ids)
        b = tree.obj_mbr
        pts = np.empty((2 * m + 1, 2), dtype=np.float32)
        pts[0:2 * m:2, 0] = b[:, 0] * ext.width + ext.xmin
        pts[0:2 * m:2, 1] = b[:, 1] * ext.height + ext.ymin
        pts[1:2 * m:2, 0] = b[:, 2] * ext.width + ext.xmin
        pts[1:2 * m:2, 1] = b[:, 3] * ext.height + ext.ymin
        pts[2 * m] = 0.0                                    # sentinel
        offsets = np.empty(m + 2, dtype=np.int64)
        offsets[:m + 1] = np.arange(m + 1, dtype=np.int64) * 2
        offsets[m + 1] = 2 * m + 1
        return GeomPool(pts, offsets)
    pts_list = []
    for pos in range(len(tree.obj_ids)):
        e = int(tree.obj_ids[pos])
        g = exact_geoms.get(e)
        if g is None:
            bx = tree.obj_mbr[pos]
            g = np.array([
                [bx[0] * ext.width + ext.xmin, bx[1] * ext.height + ext.ymin],
                [bx[2] * ext.width + ext.xmin, bx[3] * ext.height + ext.ymin],
            ])
        pts_list.append(g)
    return GeomPool.from_lists(pts_list)


@dataclasses.dataclass
class QuadStore:
    quads: np.ndarray                   # (n, 4) int64 as (g, s, p, o)
    dictionary: Dictionary
    indexes: dict                       # order name -> sorted (n, 4) int64
    numeric: dict                       # predicate id -> NumericIndex
    tree: SQuadTree | None
    cs_of_entity: dict                  # entity id -> CS id
    cs_catalog: dict                    # cs id -> frozenset(predicate ids)
    geometry_predicate: int = 0
    exact_geoms: dict = dataclasses.field(default_factory=dict)
    geom_pool: GeomPool = dataclasses.field(default_factory=GeomPool.empty)
    block: int = DEFAULT_BLOCK
    # dense numeric-literal LUT for vectorized score lookups
    _num_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=np.int64))
    _num_vals: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=np.float64))

    def values_of(self, ids_arr: np.ndarray) -> np.ndarray:
        """Vectorized literal-id -> float lookup (NaN for non-numeric)."""
        ids_arr = np.asarray(ids_arr, dtype=np.int64)
        out = np.full(len(ids_arr), np.nan)
        if len(self._num_ids) == 0:
            return out
        pos = np.searchsorted(self._num_ids, ids_arr)
        pos = np.clip(pos, 0, len(self._num_ids) - 1)
        hit = self._num_ids[pos] == ids_arr
        out[hit] = self._num_vals[pos[hit]]
        return out

    def geom_rows(self, entity_ids: np.ndarray) -> np.ndarray:
        """Entity ids -> geometry-pool rows (sentinel row when unknown)."""
        ids = np.asarray(entity_ids, dtype=np.int64)
        out = np.full(len(ids), self.geom_pool.sentinel_row, dtype=np.int64)
        t = self.tree
        if t is not None and len(t.obj_ids):
            pos = np.searchsorted(t.obj_ids, ids)
            pos = np.clip(pos, 0, len(t.obj_ids) - 1)
            hit = t.obj_ids[pos] == ids
            out[hit] = pos[hit]
        return out

    def exact_geometry(self, entity_ids: np.ndarray) -> list:
        """Exact point-set geometry per entity (falls back to MBR corners).

        Compatibility view over the CSR geometry pool: each entry is a
        float64 copy of the entity's pool run (the pool itself — see
        :class:`GeomPool` — is what the bucketed refinement kernel consumes).
        """
        rows = self.geom_rows(entity_ids)
        pts, off = self.geom_pool.points, self.geom_pool.offsets
        return [np.asarray(pts[off[r]:off[r + 1]], dtype=np.float64)
                for r in rows]

    # ------------------------------------------------------------------
    @property
    def n_quads(self) -> int:
        return len(self.quads)

    def nbytes(self) -> int:
        total = self.quads.nbytes
        for idx in self.indexes.values():
            total += idx.nbytes
        for ni in self.numeric.values():
            total += ni.values.nbytes + ni.subjects.nbytes + ni.facts.nbytes
            total += ni.block_max.nbytes + ni.block_min.nbytes
        if self.tree is not None:
            total += self.tree.nbytes()
        total += self.geom_pool.nbytes()
        return total

    # ------------------------------------------------------------------
    def scan(self, g=None, s=None, p=None, o=None,
             return_order: bool = False):
        """Range scan: returns matching rows as an (m, 4) (g,s,p,o) array.

        With ``return_order=True`` also returns the tuple of column indices
        the result rows are lexicographically sorted by — the chosen
        permutation index's columns past the bound prefix (the prefix
        columns are constant over the result, so they carry no order).
        Residual equality filters preserve row order, so the guarantee
        survives them.
        """
        bound = {G: g, S: s, P: p, O: o}
        consts = [c for c, v in bound.items() if v is not None]
        best_name, best_prefix = "spog", 0
        for name, cols in ORDERS.items():
            k = 0
            while k < 4 and cols[k] in consts:
                k += 1
            if k > best_prefix:
                best_name, best_prefix = name, k
        idx = self.indexes[best_name]
        cols = ORDERS[best_name]
        lo, hi = 0, len(idx)
        for d in range(best_prefix):
            c = cols[d]
            v = bound[c]
            col = idx[lo:hi, c]
            lo, hi = lo + np.searchsorted(col, v, "left"), \
                lo + np.searchsorted(col, v, "right")
        rows = idx[lo:hi]
        # residual filters for bound columns not covered by the sort prefix
        prefix_cols = set(cols[:best_prefix])
        for c in consts:
            if c not in prefix_cols:
                rows = rows[rows[:, c] == bound[c]]
        if return_order:
            return rows, cols[best_prefix:]
        return rows

    def spatial_box_of(self, entity_ids: np.ndarray) -> np.ndarray:
        """Normalized MBRs for spatial entity ids (NaN rows when unknown)."""
        t = self.tree
        out = np.full((len(entity_ids), 4), np.nan)
        pos = np.searchsorted(t.obj_ids, entity_ids)
        pos = np.clip(pos, 0, len(t.obj_ids) - 1)
        hit = t.obj_ids[pos] == entity_ids
        out[hit] = t.obj_mbr[pos[hit]]
        return out


def build_store(quads: np.ndarray,
                dictionary: Dictionary,
                geometry_predicate: int,
                geometries: dict,
                exact_geoms: dict | None = None,
                block: int = DEFAULT_BLOCK,
                l_max: int = 10,
                leaf_capacity: int = 64,
                extent: geometry.Extent | None = None) -> QuadStore:
    """Assemble the full store.

    quads: (n, 4) int64 (g, s, p, o) with PRE-spatial (plain) entity ids.
    geometries: plain entity id -> (xmin, ymin, xmax, ymax) world box for
        every subject that has a `geometry_predicate` fact.
    exact_geoms: plain entity id -> (m, 2) exact point-set geometry.
    """
    quads = np.asarray(quads, dtype=np.int64)

    # --- characteristic sets over all subjects --------------------------
    subj, pred = quads[:, S], quads[:, P]
    uniq_s, cs_ids = charsets.compute_characteristic_sets(subj, pred)
    cs_of = dict(zip(uniq_s.tolist(), cs_ids.tolist()))
    catalog = charsets.cs_catalog(subj, pred)

    # --- S-QuadTree over spatial entities -------------------------------
    tree = None
    mapping: dict = {}
    if geometries:
        ent = np.array(sorted(geometries.keys()), dtype=np.int64)
        boxes = np.array([geometries[int(e)] for e in ent], dtype=np.float64)
        # the geometry pool stores points as f32; the MBR must bound the
        # STORED geometry, not the caller's f64 coordinates, or a query at
        # exactly the quantized point (e.g. within-distance, dist = 0) gets
        # MBR-pruned while exact refinement would keep it. Expand each box
        # to cover the f32 round-trip of its exact points.
        if exact_geoms:
            for i, e in enumerate(ent):
                pts = exact_geoms.get(int(e))
                if pts is None or len(pts) == 0:
                    continue
                q = np.asarray(pts, dtype=np.float32).astype(np.float64)
                boxes[i, 0] = min(boxes[i, 0], q[:, 0].min())
                boxes[i, 1] = min(boxes[i, 1], q[:, 1].min())
                boxes[i, 2] = max(boxes[i, 2], q[:, 0].max())
                boxes[i, 3] = max(boxes[i, 3], q[:, 1].max())
        cs_keys, cs_vals = _sorted_lut(cs_of)
        cs_self = lut_get(cs_keys, cs_vals, ent)
        # incoming CS: subjects s with (s, p, e); outgoing CS: objects o of
        # (e, p, o) — one sort per direction + segmented unique (identical
        # to the original per-entity loop, scale-viable at 10M+ triples)
        cs_in, cs_out = _entity_cs_csr(quads, ent, cs_keys, cs_vals)
        tree = build_tree(ent, boxes, cs_self,
                          cs_in=cs_in, cs_out=cs_out,
                          l_max=l_max, leaf_capacity=leaf_capacity,
                          extent=extent)
        mapping = dict(tree.entity_to_id)

    # --- remap plain ids -> spatial ids everywhere ----------------------
    if mapping:
        lut_keys = np.array(list(mapping.keys()), dtype=np.int64)
        lut_vals = np.array(list(mapping.values()), dtype=np.int64)
        order = np.argsort(lut_keys)
        lut_keys, lut_vals = lut_keys[order], lut_vals[order]

        def remap_col(col):
            pos = np.searchsorted(lut_keys, col)
            pos = np.clip(pos, 0, len(lut_keys) - 1)
            hit = lut_keys[pos] == col
            out = col.copy()
            out[hit] = lut_vals[pos[hit]]
            return out

        quads = quads.copy()
        for c in (G, S, P, O):
            quads[:, c] = remap_col(quads[:, c])
        dictionary.remap(mapping)
        cs_of = {mapping.get(k, k): v for k, v in cs_of.items()}

    # --- permutation indexes --------------------------------------------
    indexes = {}
    for name, cols in ORDERS.items():
        keys = tuple(quads[:, c] for c in reversed(cols))
        indexes[name] = quads[np.lexsort(keys)]

    # --- per-predicate numeric indexes -----------------------------------
    numeric: dict = {}
    numeric_ids = dictionary.numeric_value
    num_ids_sorted = np.empty(0, dtype=np.int64)
    num_vals_sorted = np.empty(0, dtype=np.float64)
    if numeric_ids:
        num_ids_sorted = np.fromiter(numeric_ids.keys(), np.int64)
        order_n = np.argsort(num_ids_sorted)
        num_ids_sorted = num_ids_sorted[order_n]
        num_vals_sorted = np.fromiter(numeric_ids.values(), np.float64)[order_n]
        is_num = np.isin(quads[:, O], num_ids_sorted)
        nq = quads[is_num]
        # value lookup through the dense LUT (same floats as the dict)
        nv = num_vals_sorted[np.searchsorted(num_ids_sorted, nq[:, O])]
        for p_id in np.unique(nq[:, P]):
            sel = nq[:, P] == p_id
            rows = nq[sel]
            numeric[int(p_id)] = _build_numeric_index(
                nv[sel], rows[:, S].copy(), rows[:, O].copy(),
                rows[:, G].copy(), block)

    # remap exact geometries to spatial ids, pack them into the CSR pool
    ex = {}
    for k, v in (exact_geoms or {}).items():
        ex[int(mapping.get(k, k))] = np.asarray(v, dtype=np.float64)
    pool = _build_geom_pool(tree, ex)

    return QuadStore(quads=quads, dictionary=dictionary, indexes=indexes,
                     numeric=numeric, tree=tree, cs_of_entity=cs_of,
                     cs_catalog=catalog,
                     geometry_predicate=int(geometry_predicate),
                     exact_geoms=ex, geom_pool=pool, block=block,
                     _num_ids=num_ids_sorted, _num_vals=num_vals_sorted)
