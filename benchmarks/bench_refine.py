"""Refinement: per-pair python loop vs bucketed kernel over the CSR pool.

§3.2.4 exact-geometry validation is refinement-bound once the index has
pruned well (Geographica-style polyline/polygon workloads): the pre-pool
implementation looped candidate pairs in python, one (m, 2) x (n, 2)
broadcast each. The bucketed path gathers pairs by padded size class from
the CSR geometry pool and computes each bucket in one kernel call
(kernels/geom_refine.py). Rows sweep candidate-pair count and
points-per-geometry for both metrics; `speedup=` records looped / bucketed.
"""
from __future__ import annotations

import numpy as np

from repro.core import spatial_join
from repro.core.store import GeomPool

from . import common


def _pool(rng, n_entities: int, pts_per_geom: int, lonlat: bool) -> GeomPool:
    counts = rng.integers(max(1, pts_per_geom // 2),
                          2 * pts_per_geom, size=n_entities)
    lo, hi = ((-179.0, 179.0) if lonlat else (0.0, 100.0))
    return GeomPool.from_lists(
        [np.stack([rng.uniform(lo, hi, c), rng.uniform(lo / 2, hi / 2, c)],
                  axis=-1) for c in counts])


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for metric in ("euclid", "haversine"):
        for n_pairs, pts_per_geom in ((2000, 4), (10000, 32), (10000, 96),
                                      (30000, 32)):
            pool = _pool(rng, max(n_pairs // 8, 32), pts_per_geom,
                         lonlat=(metric == "haversine"))
            n_ent = pool.n_entities
            ra = rng.integers(0, n_ent, n_pairs).astype(np.int64)
            rb = rng.integers(0, n_ent, n_pairs).astype(np.int64)
            off = pool.offsets
            geo_a = [np.asarray(pool.points[off[r]:off[r + 1]], np.float64)
                     for r in ra]
            geo_b = [np.asarray(pool.points[off[r]:off[r + 1]], np.float64)
                     for r in rb]

            def run_looped():
                return spatial_join.exact_pair_distance_looped(
                    geo_a, geo_b, metric)

            def run_bucketed():
                return spatial_join.pool_min_dist(pool, ra, rb, metric)

            # both paths must agree before being timed
            np.testing.assert_allclose(run_bucketed(), run_looped(),
                                       rtol=1e-4, atol=1e-4)
            t_loop = common.timeit(run_looped)
            t_buck = common.timeit(run_bucketed)
            tag = f"refine/{metric}_pairs{n_pairs}_pts{pts_per_geom}"
            rows.append(common.row(f"{tag}_looped", t_loop, ""))
            rows.append(common.row(
                f"{tag}_bucketed", t_buck,
                f"speedup={t_loop / t_buck:.2f}x"))
    return rows
