"""Query representation (§2): graph patterns + spatial filter + top-k ranking.

    SELECT [projection] WHERE [patterns] FILTER [distance(a,b) < d]
    ORDER BY [ranking] LIMIT [k]

Reified statements are plain quad patterns with a bound/variable `g` slot
(``?r rdf:subject ?s . ?r rdf:predicate ?p . ?r rdf:object ?o`` collapses to
one quad pattern with g = ?r).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __repr__(self):
        return f"?{self.name}"


Term = "int | Var"


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: object
    p: object
    o: object
    g: object = None   # None = don't-care, Var = reification id, int = bound

    def vars(self) -> list[Var]:
        return [t for t in (self.g, self.s, self.p, self.o) if isinstance(t, Var)]

    def n_bound(self) -> int:
        return sum(1 for t in (self.g, self.s, self.p, self.o)
                   if t is not None and not isinstance(t, Var))


@dataclasses.dataclass(frozen=True)
class SpatialFilter:
    """FILTER(distance(?a, ?b) < dist) in world units."""
    a: Var
    b: Var
    dist: float
    metric: str = "euclid"   # or "haversine"


@dataclasses.dataclass(frozen=True)
class Ranking:
    """ORDER BY sum_i w_i * value(?v_i); descending = True for DESC."""
    terms: tuple            # ((Var, weight), ...)
    descending: bool = True

    def vars(self) -> list[Var]:
        return [v for v, _ in self.terms]


@dataclasses.dataclass(frozen=True)
class Query:
    select: tuple
    patterns: tuple
    spatial: SpatialFilter | None
    ranking: Ranking | None
    k: int = 100

    def all_vars(self) -> list[Var]:
        seen, out = set(), []
        for tp in self.patterns:
            for v in tp.vars():
                if v.name not in seen:
                    seen.add(v.name)
                    out.append(v)
        return out
