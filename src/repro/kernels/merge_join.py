"""Pallas TPU kernel: the rank pass of the two-phase sort-merge join.

The relational path (paper §3.2.1-3.2.2) joins pattern scans over the
sorted permutation indexes. core/join.py reduces every equi-join to one
primitive over *scalar composite keys*: given a sorted int64 table and a
batch of int64 probes, find each probe's lower and upper insertion rank

    lo[i] = |{ j : table[j] <  probe[i] }|
    hi[i] = |{ j : table[j] <= probe[i] }|

(`hi - lo` is the match multiplicity; the gather pass then materializes the
matching pairs with CSR cumsum/repeat arithmetic).

The engine runs without jax x64, so the wrapper (kernels/ops.py) splits the
int64 keys into (hi32, biased lo32) int32 planes on the host — comparing
(signed hi, signed lo-with-flipped-sign-bit) lexicographically equals the
int64 comparison, the same trick bloom_probe uses for its key halves — and
everything below is pure 32-bit math.

TPU has no efficient per-lane gather, so instead of a binary search the
kernel uses the VPU-friendly *counting* form: each (bb,)-probe block
broadcasts against the whole table resident in VMEM and sums the two
comparison masks over the lane axis. The table is padded with int64-max
sentinel planes, which compare strictly greater than any real probe
(core/join.py packs keys into [0, 2^63-1)), so padding never counts. Work
is O(M·N) compares versus O(M·log N) for the binary search, but it is all
8x128 VPU compares with zero control flow.

The table axis is tiled through the grid: each probe block's rank pair is
an accumulator revisited across the table-tile axis (zeroed on the first
tile via `pl.when`), so only one (bb-probe, tn-table) tile pair is VMEM
resident at a time and relations past VMEM stream through on-chip instead
of falling back. Tables that fit a single tile keep the old one-shot
schedule (the tile clamps to the padded table size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# planes of the int64-max padding sentinel: hi = 0x7FFFFFFF and
# lo = 0xFFFFFFFF ^ sign-bit-flip = 0x7FFFFFFF
_SENT = 0x7FFFFFFF


def _plane_lt_le(t_hi, t_lo, p_hi, p_lo):
    """Broadcasted (table < probe, table <= probe) on split int64 planes."""
    hi_eq = t_hi == p_hi
    lt = (t_hi < p_hi) | (hi_eq & (t_lo < p_lo))
    le = lt | (hi_eq & (t_lo == p_lo))
    return lt, le


def _kernel(t_hi_ref, t_lo_ref, p_hi_ref, p_lo_ref, lo_ref, hi_ref):
    # the (bb, 1) rank pair is an accumulator revisited across the
    # table-tile axis (out index map ignores program_id(1))
    @pl.when(pl.program_id(1) == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    lt, le = _plane_lt_le(t_hi_ref[...], t_lo_ref[...],   # (1, tn)
                          p_hi_ref[...], p_lo_ref[...])   # (bb, 1)
    lo_ref[...] += jnp.sum(lt.astype(jnp.int32), axis=1, keepdims=True)
    hi_ref[...] += jnp.sum(le.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bb", "tn", "interpret"))
def merge_join_ranks(t_hi: jnp.ndarray, t_lo: jnp.ndarray,
                     p_hi: jnp.ndarray, p_lo: jnp.ndarray,
                     bb: int = 1024, tn: int = 8192,
                     interpret: bool = False
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Counting rank pass over one probe batch.

    t_* (N,) / p_* (M,) int32 planes of sorted table keys / probe keys
    (see `ops.split_key_planes`; table sorted by the underlying int64).
    `tn` bounds the VMEM-resident table tile (lane-rounded, clamped to the
    padded table size so small tables stay single-tile).
    Returns (lo (M,), hi (M,)) int32 insertion ranks.
    """
    m = p_hi.shape[0]
    n = t_hi.shape[0]
    tn = max(-(-tn // 128) * 128, 128)
    n128 = max(-(-n // 128) * 128, 128)
    tn = min(tn, n128)
    n_pad = -(-n128 // tn) * tn
    mp = max(-(-m // bb) * bb, bb)
    t_hi = jnp.pad(t_hi, (0, n_pad - n), constant_values=_SENT)
    t_lo = jnp.pad(t_lo, (0, n_pad - n), constant_values=_SENT)
    p_hi = jnp.pad(p_hi, (0, mp - m))
    p_lo = jnp.pad(p_lo, (0, mp - m))
    lo, hi = pl.pallas_call(
        _kernel,
        grid=(mp // bb, n_pad // tn),
        in_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((bb, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32)],
        interpret=interpret,
    )(t_hi.reshape(1, -1), t_lo.reshape(1, -1),
      p_hi.reshape(-1, 1), p_lo.reshape(-1, 1))
    return lo[:m, 0], hi[:m, 0]


@functools.partial(jax.jit, static_argnames=("side",))
def merge_join_ranks_host(t_hi: jnp.ndarray, t_lo: jnp.ndarray,
                          p_hi: jnp.ndarray, p_lo: jnp.ndarray,
                          side: str = "both"):
    """CPU twin: branchless binary search, vectorized over probes — the
    loop-structured O(M·log N) form of the kernel's counting semantics
    (integer-exact, so all routes are bit-identical). log2(N) unrolled
    steps, each two gathers + one plane compare over the probe vector.
    side="left"/"right" skips the unused bound's search entirely."""
    n = t_hi.shape[0]
    if n == 0:
        z = jnp.zeros(p_hi.shape, dtype=jnp.int32)
        return (z, z) if side == "both" else z

    def bound(strict: bool) -> jnp.ndarray:
        pos = jnp.zeros(p_hi.shape, dtype=jnp.int32)
        step = 1 << max(int(n).bit_length(), 1)
        while step:
            # can we extend the all-pred prefix to pos + step?
            idx = jnp.minimum(pos + (step - 1), n - 1)
            lt, le = _plane_lt_le(jnp.take(t_hi, idx), jnp.take(t_lo, idx),
                                  p_hi, p_lo)
            pred = lt if strict else le
            pos = jnp.where((pos + step <= n) & pred, pos + step, pos)
            step >>= 1
        return pos

    if side == "left":
        return bound(True)
    if side == "right":
        return bound(False)
    return bound(True), bound(False)
