"""RecSys data substrate: synthetic interaction sequences + embedding-bag.

`InteractionStream` produces SASRec training triples (seq, pos, neg) from a
latent-factor user/item model (so BPR loss is learnable). `embedding_bag` is
the JAX EmbeddingBag (jnp.take + segment_sum) — built, not stubbed, per the
assignment note that JAX has no native EmbeddingBag.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


class InteractionStream:
    def __init__(self, n_items: int, seq_len: int, batch: int,
                 n_latent: int = 8, seed: int = 0,
                 process_index: int = 0, process_count: int = 1):
        assert batch % process_count == 0
        self.n_items = n_items
        self.seq_len = seq_len
        self.local_batch = batch // process_count
        self.seed = seed
        self.process_index = process_index
        rng = np.random.default_rng(seed)
        # latent item factors drive coherent sequences
        self.item_f = rng.normal(size=(n_items, n_latent)).astype(np.float32)

    def batch(self, step: int):
        rng = np.random.default_rng((self.seed, step, self.process_index))
        b, s = self.local_batch, self.seq_len
        user = rng.normal(size=(b, self.item_f.shape[1])).astype(np.float32)
        # per-user item affinity -> top pool -> random walk over the pool
        pool = 64
        scores = user @ self.item_f.T
        top = np.argpartition(-scores, pool, axis=1)[:, :pool]
        idx = rng.integers(0, pool, size=(b, s + 1))
        items = np.take_along_axis(top, idx, axis=1) + 1  # 0 = PAD
        seq = items[:, :-1].astype(np.int32)
        pos = items[:, 1:].astype(np.int32)
        neg = rng.integers(1, self.n_items, size=(b, s)).astype(np.int32)
        return seq, pos, neg


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  offsets: jnp.ndarray, mode: str = "sum",
                  weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """torch.nn.EmbeddingBag semantics on JAX primitives.

    table (V, D); indices (nnz,) flat bag members; offsets (B,) bag starts.
    Returns (B, D) reduced embeddings. mode: sum | mean | max.
    """
    nnz = indices.shape[0]
    b = offsets.shape[0]
    rows = jnp.take(table, indices, axis=0)                   # gather
    if weights is not None:
        rows = rows * weights[:, None]
    # bag id per member: searchsorted over offsets
    bag = jnp.searchsorted(offsets, jnp.arange(nnz), side="right") - 1
    if mode == "sum":
        return jax.ops.segment_sum(rows, bag, num_segments=b)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, bag, num_segments=b)
        c = jax.ops.segment_sum(jnp.ones((nnz, 1), rows.dtype), bag,
                                num_segments=b)
        return s / jnp.maximum(c, 1.0)
    if mode == "max":
        return jax.ops.segment_max(rows, bag, num_segments=b)
    raise ValueError(mode)
