"""Model zoo smoke + property tests (reduced configs, CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (equivariant, gnn, graphcast, moe, sasrec,
                          transformer)


def _finite(x):
    assert np.isfinite(np.asarray(x, dtype=np.float32)).all()


# ------------------------------------------------------------ transformer ---
@pytest.mark.parametrize("act,glu,kv", [("silu", True, 2), ("gelu", True, 4),
                                        ("sq_relu", False, 1)])
def test_transformer_forward_and_loss(act, glu, kv):
    cfg = transformer.TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=kv, head_dim=16,
        d_ff=128, vocab=128, act=act, glu=glu, dtype="float32", remat=False,
        loss_chunks=2)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    h = transformer.forward(params, tokens, cfg)
    assert h.shape == (2, 16, 64)
    _finite(h)
    loss = transformer.lm_loss(params, tokens, cfg)
    _finite(loss)
    g = jax.grad(transformer.lm_loss)(params, tokens, cfg)
    _finite(g["embed"])


def test_transformer_decode_matches_forward():
    cfg = transformer.TransformerConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=64, dtype="float32", remat=False)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 64)
    h = transformer.forward(params, tokens, cfg)
    full_logits = transformer.logits_fn(params, h, cfg)
    cache = transformer.init_cache(cfg, 1, 8)
    for t in range(8):
        logits, cache = transformer.decode_step(
            params, cache, tokens[:, t], jnp.array([t]), cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_attention_restricts_context():
    cfg = transformer.TransformerConfig(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=64, dtype="float32", remat=False, window=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    # perturb a token far outside the window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 1) % 64)
    h1 = transformer.forward(params, t1, cfg)
    h2 = transformer.forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(h1[0, -1]), np.asarray(h2[0, -1]),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- moe ---
def test_moe_forward_loss_and_expert_padding():
    cfg = moe.MoEConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab=64, n_experts=6, n_experts_padded=8, top_k=2, d_ff_expert=32,
        n_shared=1, dtype="float32", remat=False, loss_chunks=1)
    params = moe.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    h, aux = moe.forward(params, tokens, cfg)
    assert h.shape == (2, 16, 32)
    _finite(h)
    assert float(aux) > 0.0
    loss = moe.lm_loss(params, tokens, cfg)
    _finite(loss)
    g = jax.grad(moe.lm_loss)(params, tokens, cfg)
    _finite(g["layers"]["we_up"])
    # padding experts must never receive tokens: grads exactly zero there
    gpad = np.asarray(g["layers"]["we_up"])[:, cfg.n_experts:]
    np.testing.assert_array_equal(gpad, np.zeros_like(gpad))


def test_moe_capacity_drops_are_bounded():
    cfg = moe.MoEConfig(
        n_layers=1, d_model=16, n_heads=1, n_kv_heads=1, head_dim=16,
        vocab=32, n_experts=4, n_experts_padded=4, top_k=1, d_ff_expert=16,
        capacity_factor=8.0, dtype="float32", remat=False)
    params = moe.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    y, aux = moe.moe_ffn(lp, x, cfg)
    # with a huge capacity factor, every token must be routed (non-zero out)
    assert float(jnp.abs(y).sum()) > 0
    norms = jnp.sum(jnp.abs(y), axis=-1)
    assert float((norms > 0).mean()) == 1.0


# --------------------------------------------------------------------- gnn ---
def _rand_graph(rng, n=50, e=200, f=8):
    x = rng.normal(size=(n, f)).astype(np.float32)
    edges = rng.integers(0, n, size=(2, e)).astype(np.int32)
    return x, edges


@pytest.mark.parametrize("arch", ["gcn", "sage"])
def test_gnn_forward_and_grad(arch):
    rng = np.random.default_rng(0)
    x, edges = _rand_graph(rng)
    cfg = gnn.GNNConfig(arch=arch, n_layers=2, d_in=8, d_hidden=16, d_out=4)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    out = gnn.forward(params, jnp.asarray(x), jnp.asarray(edges), cfg)
    assert out.shape == (50, 4)
    _finite(out)
    labels = jnp.asarray(rng.integers(0, 4, size=50).astype(np.int32))
    mask = jnp.ones(50, dtype=bool)
    g = jax.grad(gnn.nll_loss)(params, jnp.asarray(x), jnp.asarray(edges),
                               labels, mask, cfg)
    _finite(g["layers"][0]["w"])


def test_gcn_isolated_node_keeps_self_features():
    cfg = gnn.GNNConfig(arch="gcn", n_layers=1, d_in=4, d_hidden=4, d_out=4)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.eye(4)
    edges = jnp.array([[1], [2]], dtype=jnp.int32)  # node 0 isolated
    out = gnn.forward(params, x, edges, cfg)
    _finite(out)
    assert float(jnp.abs(out[0]).sum()) > 0  # self loop survives


# --------------------------------------------------------------- graphcast ---
def test_graphcast_forward():
    rng = np.random.default_rng(1)
    cfg = graphcast.GraphCastConfig(n_layers=3, d_hidden=32, n_vars=11,
                                    dtype="float32", remat=False)
    n_grid, n_mesh = 40, 12
    gx = jnp.asarray(rng.normal(size=(n_grid, 11)).astype(np.float32))
    g2m = jnp.asarray(rng.integers(0, [[n_grid], [n_mesh]], size=(2, 80))
                      .astype(np.int32))
    me = jnp.asarray(rng.integers(0, n_mesh, size=(2, 50)).astype(np.int32))
    m2g = jnp.asarray(rng.integers(0, [[n_mesh], [n_grid]], size=(2, 80))
                      .astype(np.int32))
    params = graphcast.init_params(jax.random.PRNGKey(0), cfg)
    out = graphcast.forward(params, gx, g2m, me, m2g, n_mesh, cfg)
    assert out.shape == (n_grid, 11)
    _finite(out)
    g = jax.grad(graphcast.mse_loss)(params, gx, gx, g2m, me, m2g, n_mesh, cfg)
    _finite(g["grid_embed"])


# ------------------------------------------------------------------ nequip ---
def _random_molecule(rng, n=12):
    pos = rng.normal(size=(n, 3)).astype(np.float32) * 2.0
    species = rng.integers(0, 4, size=n).astype(np.int32)
    d = np.sqrt(((pos[:, None] - pos[None]) ** 2).sum(-1))
    i, j = np.nonzero((d < 5.0) & (d > 0))
    return species, pos, np.stack([i, j]).astype(np.int32)


def test_nequip_forward_finite():
    rng = np.random.default_rng(2)
    species, pos, edges = _random_molecule(rng)
    cfg = equivariant.NequIPConfig(n_layers=2, n_channels=8)
    params = equivariant.init_params(jax.random.PRNGKey(0), cfg)
    e = equivariant.forward(params, jnp.asarray(species), jnp.asarray(pos),
                            jnp.asarray(edges), cfg)
    _finite(e)


def _rotation(rng):
    a = rng.normal(size=(3, 3))
    q, _ = np.linalg.qr(a)
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q.astype(np.float32)


@pytest.mark.parametrize("seed", range(3))
def test_nequip_energy_rotation_invariant(seed):
    """E(3) equivariance: total energy invariant under rotation+translation.

    This exercises the full chain (spherical harmonics, Gaunt tensor-product
    coupling, norm gates) — any wrong CG phase breaks it.
    """
    rng = np.random.default_rng(seed)
    species, pos, edges = _random_molecule(rng)
    cfg = equivariant.NequIPConfig(n_layers=3, n_channels=8)
    params = equivariant.init_params(jax.random.PRNGKey(seed), cfg)
    e1 = equivariant.forward(params, jnp.asarray(species), jnp.asarray(pos),
                             jnp.asarray(edges), cfg)
    r = _rotation(rng)
    pos2 = pos @ r.T + rng.normal(size=(1, 3)).astype(np.float32)
    e2 = equivariant.forward(params, jnp.asarray(species), jnp.asarray(pos2),
                             jnp.asarray(edges), cfg)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)


def test_nequip_permutation_invariant():
    rng = np.random.default_rng(5)
    species, pos, edges = _random_molecule(rng)
    cfg = equivariant.NequIPConfig(n_layers=2, n_channels=8)
    params = equivariant.init_params(jax.random.PRNGKey(1), cfg)
    e1 = equivariant.forward(params, jnp.asarray(species), jnp.asarray(pos),
                             jnp.asarray(edges), cfg)
    perm = rng.permutation(len(species))
    inv = np.argsort(perm)
    e2 = equivariant.forward(params, jnp.asarray(species[perm]),
                             jnp.asarray(pos[perm]),
                             jnp.asarray(inv[np.asarray(edges)]), cfg)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)


def test_gaunt_selection_rules():
    from repro.models.equivariant import gaunt
    # parity-forbidden path integrates to ~0
    t = gaunt(1, 1, 1)
    assert np.abs(t).max() < 1e-8
    # allowed paths are nonzero and normalized
    assert np.abs(gaunt(1, 1, 2)).max() > 0.1
    np.testing.assert_allclose(np.linalg.norm(gaunt(1, 1, 0)), 1.0, rtol=1e-6)


# ------------------------------------------------------------------ sasrec ---
def test_sasrec_forward_and_loss():
    cfg = sasrec.SASRecConfig(n_items=500, embed_dim=16, n_blocks=2,
                              seq_len=10)
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    seq = jnp.asarray(rng.integers(1, 500, size=(4, 10)).astype(np.int32))
    st = sasrec.user_state(params, seq, cfg)
    assert st.shape == (4, 16)
    cands = jnp.asarray(rng.integers(1, 500, size=(4, 20)).astype(np.int32))
    sc = sasrec.score_candidates(params, st, cands)
    assert sc.shape == (4, 20)
    _finite(sc)
    pos = jnp.asarray(rng.integers(1, 500, size=(4, 10)).astype(np.int32))
    neg = jnp.asarray(rng.integers(1, 500, size=(4, 10)).astype(np.int32))
    loss = sasrec.bpr_loss(params, seq, pos, neg, cfg)
    _finite(loss)
    g = jax.grad(sasrec.bpr_loss)(params, seq, pos, neg, cfg)
    _finite(g["item_embed"])


def test_sasrec_padding_masked():
    cfg = sasrec.SASRecConfig(n_items=100, embed_dim=8, n_blocks=1, seq_len=6)
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    s1 = jnp.array([[0, 0, 5, 6, 7, 8]], dtype=jnp.int32)
    s2 = jnp.array([[0, 0, 5, 6, 7, 8]], dtype=jnp.int32).at[0, 0].set(0)
    np.testing.assert_allclose(
        np.asarray(sasrec.user_state(params, s1, cfg)),
        np.asarray(sasrec.user_state(params, s2, cfg)), rtol=1e-6)
