"""Fault-tolerance layer: failover chains, breakers, deadlines, isolation.

The load-bearing property mirrors the serving suite's: every backend of
every op is bit-identical, so *any* injected failure — op exceptions,
watchdog timeouts, detected corruption, whole-launch crashes — must leave
engine and serve results exactly equal to a fault-free run. Deadlines trade
completeness for latency instead: a truncated query returns `partial=True`
results whose θ-derived `score_bound` certifiably dominates everything it
left out (verified against the full-scan oracle).
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core import fault
from repro.core.baselines import FullScanEngine
from repro.core.executor import ExecConfig, StreakEngine
from repro.core.policy import BackendPolicy
from repro.core.topk import TopK
from repro.data.synth_rdf import make_lgd
from repro.serve.spatial import SpatialRequest, SpatialServeEngine

FaultPlan, FaultRule, QueryDeadline = (fault.FaultPlan, fault.FaultRule,
                                       fault.QueryDeadline)


@pytest.fixture(autouse=True)
def _clean_fault_state():
    """Every test starts and ends with no plan, no breakers, no watchdog."""
    fault.STATE.reset()
    yield
    fault.STATE.reset()


@pytest.fixture(scope="module")
def lgd():
    return make_lgd(n_per_class=60, seed=0, block=64)


def _run(lgd, q, policy=None, deadline=None, **cfg):
    if policy is not None:
        cfg["policy"] = policy
    eng = StreakEngine(lgd.store, ExecConfig(fused_batch_cols=256, **cfg))
    return eng.execute(q, deadline=deadline)


def _assert_same(a, b):
    np.testing.assert_array_equal(a[0], b[0])
    assert a[1].keys() == b[1].keys()
    for c in b[1]:
        np.testing.assert_array_equal(a[1][c], b[1][c])


# ------------------------------------------------------- kernel failover ---
# each instrumented op, with a policy whose plan actually dispatches it
OP_CONFIGS = [
    ("distance_join_matrix", BackendPolicy(join="kernel")),
    ("fused_topk_join", BackendPolicy(join="fused")),
    ("bucketed_min_core", BackendPolicy()),
    ("merge_join_ranks", BackendPolicy(impl="merge")),
    ("tree_descend", BackendPolicy(descend="kernel")),
    ("bloom_probe", BackendPolicy(probe="kernel")),
]


@pytest.mark.parametrize("op,policy", OP_CONFIGS, ids=[o for o, _ in OP_CONFIGS])
def test_each_op_failing_once_is_bit_identical(lgd, op, policy):
    q = lgd.queries[0]
    want = _run(lgd, q, policy=policy)
    plan = FaultPlan(rules=(FaultRule(op=op, call=0),))
    with fault.fault_plan(plan):
        got = _run(lgd, q, policy=policy)
    assert plan.injected > 0, f"{op} was never dispatched under {policy}"
    assert fault.STATE.stats.fallbacks > 0
    _assert_same(got, want)


def test_seeded_random_failure_rate_is_bit_identical(lgd):
    pol = BackendPolicy(join="fused", descend="kernel", impl="merge")
    wants = [_run(lgd, q, policy=pol) for q in lgd.queries[:3]]
    plan = FaultPlan(rate=0.05, seed=3)
    with fault.fault_plan(plan):
        gots = [_run(lgd, q, policy=pol) for q in lgd.queries[:3]]
    assert plan.injected > 0
    for got, want in zip(gots, wants):
        _assert_same(got, want)


def test_corrupt_then_detect_recovers_bit_identical(lgd):
    q = lgd.queries[0]
    pol = BackendPolicy(join="fused")
    want = _run(lgd, q, policy=pol)
    plan = FaultPlan(rules=(FaultRule(op="fused_topk_join", mode="corrupt"),))
    with fault.fault_plan(plan):
        got = _run(lgd, q, policy=pol)
    assert plan.injected > 0
    assert fault.STATE.stats.corruptions_detected > 0
    _assert_same(got, want)


def test_watchdog_timeout_falls_back_bit_identical(lgd):
    q = lgd.queries[0]
    pol = BackendPolicy(join="kernel")
    want = _run(lgd, q, policy=pol)
    plan = FaultPlan(rules=(
        FaultRule(op="distance_join_matrix", call=0, mode="delay",
                  delay_s=0.5),))
    with fault.fault_plan(plan), fault.watchdog(0.05):
        got = _run(lgd, q, policy=pol)
    assert fault.STATE.stats.timeouts > 0
    _assert_same(got, want)


def test_fallback_exhausted_when_every_attempt_fails():
    from repro.kernels import ops
    plan = FaultPlan(rules=(FaultRule(op="bloom_probe", attempts=99),))
    bits = np.zeros((4, 8), np.uint32)
    keys = np.arange(4, dtype=np.int64)
    with fault.fault_plan(plan):
        with pytest.raises(fault.FallbackExhausted):
            ops.bloom_probe(bits, keys)
    assert fault.STATE.stats.exhausted == 1
    # clean chain works again (and closes the breakers it failed)
    assert not ops.bloom_probe(bits, keys).any()


# -------------------------------------------------------- circuit breaker ---
def test_circuit_breaker_state_machine():
    br = fault.CircuitBreaker(threshold=3, cooldown_s=0.05)
    assert br.allow() and not br.open
    br.fail(), br.fail()
    assert br.allow() and not br.open        # under threshold: still closed
    br.fail()
    assert br.open and not br.allow()        # opened, inside cooldown
    time.sleep(0.06)
    assert br.allow()                        # half-open: exactly one probe
    assert not br.allow()
    br.fail()                                # probe failed: reopen + recool
    assert br.open and not br.allow()
    time.sleep(0.06)
    assert br.allow()
    br.ok()                                  # probe succeeded: closed again
    assert not br.open and br.allow()


def test_open_breaker_demotes_policy_resolution():
    from repro.kernels import ops
    node_keys = np.zeros((4, 4), np.int64)
    boxes = np.zeros((1, 2, 4), np.int64)
    cs = np.ones(4, bool)
    plan = FaultPlan(rules=(FaultRule(op="tree_descend", attempts=99),))
    with fault.fault_plan(plan):
        for _ in range(fault.STATE.breaker_threshold):
            with pytest.raises(fault.FallbackExhausted):
                ops.tree_descend(node_keys, cs, boxes, backend="kernel")
    assert fault.STATE.breaker("tree_descend", "kernel").open
    # plan-time reroute: later plans skip the broken backend entirely
    assert BackendPolicy(descend="kernel").resolve().descend == "numpy"
    assert fault.STATE.stats.policy_demotions > 0
    # untouched stages resolve as requested
    assert BackendPolicy(probe="kernel").resolve().probe == "kernel"
    fault.STATE.reset()
    assert BackendPolicy(descend="kernel").resolve().descend == "kernel"


# ----------------------------------------------------- deadlines / anytime --
def _oracle_all(lgd, q):
    """Every result's key (not just top-k), via the full-scan oracle."""
    scores, _, _ = FullScanEngine(lgd.store).execute(
        dataclasses.replace(q, k=10 ** 7))
    return scores if q.ranking.descending else -scores


def test_deadline_block_budget_returns_certified_partial(lgd):
    q = dataclasses.replace(lgd.queries[0], k=120)
    scores, rows, stats = _run(lgd, q, deadline=QueryDeadline(max_blocks=1))
    assert stats.partial and stats.deadline_expired
    assert stats.driver_blocks == 1
    assert stats.score_bound is not None
    assert rows.n == len(scores) < 120      # genuinely truncated
    # certification: every result OUTSIDE the returned set has a key at or
    # below the bound (exact multiset difference — both engines accumulate
    # identical f64 keys)
    keys = scores if q.ranking.descending else -scores
    leftover = list(np.sort(_oracle_all(lgd, q))[::-1])
    for k in np.sort(keys)[::-1]:
        leftover.remove(k)                  # raises if not a true result
    if leftover:
        assert max(leftover) <= stats.score_bound


def test_deadline_already_expired_returns_empty_partial(lgd):
    q = lgd.queries[0]
    dl = QueryDeadline(seconds=0.0)
    scores, rows, stats = _run(lgd, q, deadline=dl)
    assert stats.partial and len(scores) == 0 and rows.n == 0
    # nothing returned: the bound must dominate EVERY result
    assert _oracle_all(lgd, q).max() <= stats.score_bound


def test_no_deadline_complete_run_unchanged(lgd):
    q = lgd.queries[0]
    scores, _, stats = _run(lgd, q, deadline=QueryDeadline(max_blocks=10 ** 6))
    want, _, wstats = _run(lgd, q)
    np.testing.assert_array_equal(scores, want)
    assert not stats.partial and not stats.deadline_expired
    # a complete run's bound is the final θ
    assert stats.score_bound == wstats.score_bound


def test_serve_deadline_tenant_partial_others_exact(lgd):
    qs = [dataclasses.replace(q, k=40) for q in lgd.queries[:4]]
    serial = [_run(lgd, q) for q in qs]
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=2)
    reqs = [SpatialRequest(rid=i, query=q) for i, q in enumerate(qs)]
    reqs[1].deadline = QueryDeadline(max_blocks=1)
    for r in reqs:
        srv.submit(r)
    srv.run()
    assert all(r.done and r.error is None for r in reqs)
    assert reqs[1].stats.partial
    assert srv.stats.deadline_partials == 1
    for i in (0, 2, 3):
        np.testing.assert_array_equal(reqs[i].scores, serial[i][0])


# --------------------------------------------------- serve crash isolation --
def _serve(lgd, queries, **kw):
    cfg = ExecConfig(policy=BackendPolicy(join="fused"), fused_batch_cols=256)
    srv = SpatialServeEngine(lgd.store, cfg, max_slots=3, **kw)
    return srv, srv.serve(queries)


def test_serve_transient_fault_retries_bit_identical(lgd):
    qs = [dataclasses.replace(q, k=30) for q in lgd.queries[:4]]
    # probe run: an empty plan's per-op counters reveal how many dispatches
    # the clean serve makes, so the injected call index is always mid-serve
    probe = FaultPlan()
    with fault.fault_plan(probe):
        _, clean = _serve(lgd, qs)
    ncalls = probe.calls.get("fused_topk_join", 0)
    assert ncalls >= 2, "serve run never reached the fused join"
    fault.STATE.reset()
    # defeat the whole chain on one mid-serve dispatch: FallbackExhausted
    # surfaces to the slot loop, the riders restart from fresh cursors
    plan = FaultPlan(rules=(
        FaultRule(op="fused_topk_join", call=ncalls // 2, attempts=99),))
    with fault.fault_plan(plan):
        srv, reqs = _serve(lgd, qs)
    assert plan.injected > 0
    assert srv.stats.faults >= 1 and srv.stats.retries >= 1
    assert all(r.done and r.error is None for r in reqs)
    for req, want in zip(reqs, clean):
        np.testing.assert_array_equal(req.scores, want.scores)
        assert req.rows.n == want.rows.n


def test_serve_retries_exhausted_surfaces_error_and_terminates(lgd):
    qs = [dataclasses.replace(q, k=30) for q in lgd.queries[:3]]
    plan = FaultPlan(rules=(
        FaultRule(op="fused_topk_join", attempts=99),))   # every call dies
    with fault.fault_plan(plan):
        srv, reqs = _serve(lgd, qs, max_retries=1)
    assert all(r.done for r in reqs)                      # loop terminated
    assert all(isinstance(r.error, fault.TRANSIENT) for r in reqs)
    assert all(len(r.scores) == 0 for r in reqs)
    assert srv.stats.failed_requests == len(qs)
    assert srv.stats.retries >= 1


def test_admission_failure_surfaces_not_drops(lgd):
    good = [dataclasses.replace(q, k=20) for q in lgd.queries[:2]]
    bad = dataclasses.replace(good[0], spatial=None)      # cursor ctor raises
    serial = [_run(lgd, q) for q in good]
    srv = SpatialServeEngine(lgd.store, ExecConfig(), max_slots=2)
    reqs = srv.serve([good[0], bad, good[1]])
    assert all(r.done for r in reqs)
    assert reqs[1].error is not None and len(reqs[1].scores) == 0
    assert srv.stats.admission_failures == 1
    for req, want in zip((reqs[0], reqs[2]), serial):
        np.testing.assert_array_equal(req.scores, want[0])


def test_stream_entry_fault_isolates_one_rider():
    from repro.core.spatial_join import StreamEntry, fused_stream_join_multi
    rng = np.random.default_rng(9)

    def boxes(n):
        lo = rng.random((n, 2))
        return np.concatenate([lo, lo + 0.03 * rng.random((n, 2))], axis=1)

    drv, dvn = boxes(30), boxes(120)
    dk, vk = rng.random(30), rng.random(120)
    acc: list = []

    def boom(pi, pj):
        raise RuntimeError("tenant bug")

    entries = [
        StreamEntry(drv, dvn, dk, vk, 0.4, 8, theta_fn=lambda: -np.inf,
                    emit=boom),
        StreamEntry(drv, dvn, dk, vk, 0.4, 8, theta_fn=lambda: -np.inf,
                    emit=lambda pi, pj: acc.append((pi, pj))),
    ]
    fused_stream_join_multi(entries, batch_cols=64)
    assert isinstance(entries[0].error, RuntimeError)     # faulted rider
    assert entries[1].error is None and acc               # survivor emitted


# ------------------------------------------------ TopK anytime θ property ---
def test_topk_theta_bounds_every_dropped_score():
    """Backbone of the anytime guarantee: at ANY truncation point, θ is a
    valid upper bound on every score the heap has seen and dropped."""
    from repro.core.join import Relation
    rng = np.random.default_rng(11)
    topk = TopK(k=12, descending=True)
    seen: list = []
    for step in range(30):
        batch = rng.normal(size=rng.integers(1, 9)) * 10
        rows = Relation({"r": np.arange(len(batch))})
        topk.push(batch, rows)
        seen.extend(batch.tolist())
        kept, _ = topk.results()
        assert len(kept) == min(len(seen), 12)
        dropped = list(np.sort(seen))
        for s in kept:                       # exact multiset difference
            dropped.remove(s)
        if not topk.full:
            assert topk.theta == -np.inf and not dropped
        elif dropped:
            assert max(dropped) <= topk.theta
            # and θ is attained, not loose: it IS the k-th kept score
            assert topk.theta == min(kept)
