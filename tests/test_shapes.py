"""Geographica-shaped query diversity: range / within-distance / kNN /
non-top-k spatial join, differential vs the FullScanEngine brute-force
oracles, plus the degenerate-geometry and empty/short-result edge cases
those shapes flush out (coincident points, zero-area MBRs, k > candidates,
all-pruned shards, compressed E-list gaps)."""
import dataclasses

import numpy as np
import pytest

from repro.core import spatial_join
from repro.core.baselines import FullScanEngine
from repro.core.executor import ExecConfig, StreakEngine
from repro.core.fault import QueryDeadline
from repro.core.planner import plan_query
from repro.core.policy import BackendPolicy
from repro.core.query import Query, Ranking, SpatialFilter, TriplePattern, Var
from repro.core.shard import shard_store
from repro.data.synth_rdf import make_lgd


@pytest.fixture(scope="module")
def ds():
    return make_lgd(n_per_class=80, seed=11, block=64)


@pytest.fixture(scope="module")
def oracle(ds):
    return FullScanEngine(ds.store)


def _shape_query(ds, spatial, cls_a="class:hotel", cls_b="class:park",
                 extra_b=()):
    ns = ds.ns
    pa, pb = Var("place"), Var("nplace")
    patterns = [
        TriplePattern(pa, Var("typePred1"), ns[cls_a], g=Var("r")),
        TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
        TriplePattern(pa, ns["hasGeometry"], Var("g1")),
        TriplePattern(pb, Var("typePred2"), ns[cls_b], g=Var("r1")),
        TriplePattern(Var("r1"), ns["hasConfidence"], Var("conf1")),
        TriplePattern(pb, ns["hasGeometry"], Var("g2")),
    ]
    for p in extra_b:
        patterns.append(TriplePattern(pb, ns[p], Var(f"b_{p}")))
    return Query(select=(pa, pb), patterns=tuple(patterns),
                 spatial=spatial, ranking=None)


def _assert_identical(engine, oracle, q):
    es, erows, estats = engine.execute(q)
    os_, orows, _ = oracle.execute(q)
    np.testing.assert_array_equal(es, os_)
    assert sorted(erows.keys()) == sorted(orows.keys())
    for c in orows.keys():
        np.testing.assert_array_equal(erows[c], orows[c])
    return es, erows, estats


# ------------------------------------------------------------ shape model --
def test_query_shape_classification():
    g1, g2 = Var("g1"), Var("g2")
    rank = Ranking(((Var("c"), 1.0),))
    topk = Query((), (), SpatialFilter(g1, g2, 5.0), rank)
    assert topk.shape() == "topk"
    assert Query((), (), SpatialFilter(g1, g2, 5.0), None).shape() == "join"
    assert Query((), (), SpatialFilter(g1, g2, knn=3), None).shape() == "knn"
    assert Query((), (), SpatialFilter(g1, None, window=(0, 0, 1, 1)),
                 None).shape() == "range"
    assert Query((), (), SpatialFilter(g1, None, dist=1.0, center=(0, 0)),
                 None).shape() == "within"
    assert Query((), (), None, rank).shape() == "scan"


def test_planner_rejects_malformed_shapes(ds):
    rank = Ranking(((Var("conf"), 1.0),))
    q = _shape_query(ds, SpatialFilter(Var("g1"), None, window=(0, 0, 9, 9)))
    with pytest.raises(ValueError, match="selections"):
        plan_query(ds.store, dataclasses.replace(q, ranking=rank))
    with pytest.raises(ValueError, match="unary"):
        plan_query(ds.store, dataclasses.replace(
            q, spatial=SpatialFilter(Var("g1"), Var("g2"),
                                     window=(0, 0, 9, 9))))
    with pytest.raises(ValueError, match="spatial.b"):
        plan_query(ds.store, dataclasses.replace(
            q, spatial=SpatialFilter(Var("g1"), None, knn=3)))
    with pytest.raises(ValueError, match="positive"):
        StreakEngine(ds.store).execute(dataclasses.replace(
            q, spatial=SpatialFilter(Var("g1"), Var("g2"), knn=0)))


# ------------------------------------------- differential, backends/shards --
SHAPES = {
    "range": SpatialFilter(Var("g1"), None, window=(15.0, 10.0, 70.0, 60.0)),
    "range_sliver": SpatialFilter(Var("g1"), None,
                                  window=(40.0, 0.0, 40.5, 100.0)),
    "range_outside": SpatialFilter(Var("g1"), None,
                                   window=(400.0, 400.0, 500.0, 500.0)),
    "within": SpatialFilter(Var("g1"), None, dist=18.0, center=(50.0, 30.0)),
    "within_tiny": SpatialFilter(Var("g1"), None, dist=0.01,
                                 center=(50.0, 30.0)),
    "join": SpatialFilter(Var("g1"), Var("g2"), dist=5.0),
    "join_empty": SpatialFilter(Var("g1"), Var("g2"), dist=1e-12),
    "knn1": SpatialFilter(Var("g1"), Var("g2"), knn=1),
    "knn4": SpatialFilter(Var("g1"), Var("g2"), knn=4),
    "knn_over": SpatialFilter(Var("g1"), Var("g2"), knn=10 ** 7),
}


@pytest.mark.parametrize("name", sorted(SHAPES))
def test_shapes_match_oracle(ds, oracle, name):
    q = _shape_query(ds, SHAPES[name])
    _assert_identical(StreakEngine(ds.store), oracle, q)


@pytest.mark.parametrize("policy", [
    BackendPolicy(join="kernel"),
    BackendPolicy(join="fused", probe="interpret", rank="interpret",
                  descend="interpret"),
])
@pytest.mark.parametrize("name", ["range", "within", "join", "knn4"])
def test_shapes_match_oracle_across_backends(ds, oracle, name, policy):
    q = _shape_query(ds, SHAPES[name])
    _assert_identical(StreakEngine(ds.store, ExecConfig(policy=policy)),
                      oracle, q)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("name", ["range", "within", "join", "knn4",
                                  "knn_over"])
def test_shapes_sharded_match_oracle(ds, oracle, name, n_shards):
    q = _shape_query(ds, SHAPES[name])
    sharded = shard_store(ds.store, n_shards)
    _assert_identical(StreakEngine(sharded), oracle, q)


def test_shape_results_use_canonical_order(ds):
    """Entity-major, then distance, then remaining columns by name — fully
    deterministic, so repeat runs are bit-identical."""
    q = _shape_query(ds, SHAPES["join"])
    eng = StreakEngine(ds.store)
    s1, r1, _ = eng.execute(q)
    s2, r2, _ = eng.execute(q)
    np.testing.assert_array_equal(s1, s2)
    for c in r1.keys():
        np.testing.assert_array_equal(r1[c], r2[c])
    a = r1["place"]
    assert np.all(a[:-1] <= a[1:])          # entity-major order
    grp_scores = np.flatnonzero(a[:-1] == a[1:])
    assert np.all(s1[grp_scores] <= s1[grp_scores + 1])


# -------------------------------------------------- S3: kNN / empty edges --
def test_knn_short_lists_when_k_exceeds_candidates(ds, oracle):
    q = _shape_query(ds, SHAPES["knn_over"])
    es, erows, estats = _assert_identical(StreakEngine(ds.store), oracle, q)
    # every driver hotel pairs with EVERY park: short of k, never padded
    n_parks = len(np.unique(erows["nplace"]))
    counts = np.unique(erows["place"], return_counts=True)[1]
    assert set(counts.tolist()) == {n_parks}
    assert estats.results_considered == erows.n


def test_knn_empty_driven_side(ds, oracle):
    # police entities have no "area" predicate: the driven side is empty
    q = _shape_query(ds, SpatialFilter(Var("g1"), Var("g2"), knn=3),
                     cls_b="class:police", extra_b=("area",))
    es, erows, estats = _assert_identical(StreakEngine(ds.store), oracle, q)
    assert erows.n == 0 and len(es) == 0
    assert estats.results_considered == 0
    assert not estats.partial


def test_join_empty_result_is_well_formed(ds, oracle):
    q = _shape_query(ds, SHAPES["join_empty"])
    es, erows, estats = _assert_identical(StreakEngine(ds.store), oracle, q)
    assert erows.n == 0 and len(es) == 0
    assert set(erows.keys()) >= {"place", "nplace"}
    assert estats.driver_blocks >= 1
    assert estats.plan_log and set(estats.plan_log) == {"S"}


def test_range_all_pruned_shards(ds, oracle):
    """A window beyond every shard's extent: every shard's SIP material is
    empty, yet the result is a well-formed empty relation."""
    q = _shape_query(ds, SHAPES["range_outside"])
    sharded = shard_store(ds.store, 4)
    es, erows, estats = _assert_identical(StreakEngine(sharded), oracle, q)
    assert erows.n == 0
    assert estats.driven_rows_after_sip == 0


def test_shape_stats_are_consistent(ds):
    for name in ("range", "within", "join", "knn4"):
        q = _shape_query(ds, SHAPES[name])
        _, rows, stats = StreakEngine(ds.store).execute(q)
        assert stats.driver_blocks >= 1
        assert stats.plan_s == stats.driver_blocks
        assert len(stats.plan_log) == stats.driver_blocks
        assert stats.results_considered == rows.n
        assert not stats.early_terminated


def test_deadline_marks_partial_join(ds):
    q = _shape_query(ds, SHAPES["join"])
    eng = StreakEngine(ds.store, ExecConfig(block=8))
    scores, rows, stats = eng.execute(
        q, deadline=QueryDeadline(max_blocks=1))
    assert stats.deadline_expired and stats.partial
    full_scores, _, _ = eng.execute(q)
    assert len(scores) <= len(full_scores)


def test_deadline_marks_partial_knn(ds):
    q = _shape_query(ds, SHAPES["knn4"])
    scores, rows, stats = StreakEngine(ds.store).execute(
        q, deadline=QueryDeadline(max_blocks=1))
    assert stats.deadline_expired and stats.partial


# --------------------------------------- S2: degenerate geometry handling --
def test_pool_min_dist_coincident_points_exactly_zero(ds):
    pool = ds.store.geom_pool
    rows = np.arange(8, dtype=np.int64)
    d = spatial_join.pool_min_dist(pool, rows, rows, "euclid")
    np.testing.assert_array_equal(d, np.zeros(8))
    keep = spatial_join.refine(rows, rows, pool, rows, rows, 0.0, "euclid")
    assert keep.all()


def test_pool_point_min_dist_exact_zero_and_inf(ds):
    pool = ds.store.geom_pool
    p = pool.points[pool.offsets[3]].astype(np.float64)
    d = spatial_join.pool_point_min_dist(pool, np.array([3]), p)
    assert d[0] == 0.0
    far = spatial_join.pool_point_min_dist(pool, np.array([3]),
                                           np.array([1e9, 1e9]))
    assert np.isfinite(far[0]) and far[0] > 0


def test_pool_points_in_box_zero_area_window(ds):
    pool = ds.store.geom_pool
    p = pool.points[pool.offsets[3]].astype(np.float64)
    hit = spatial_join.pool_points_in_box(
        pool, np.array([3]), (p[0], p[1], p[0], p[1]))
    assert bool(hit[0])
    miss = spatial_join.pool_points_in_box(
        pool, np.array([3]), (p[0] + 1e-3, p[1], p[0] + 1e-3, p[1]))
    assert not bool(miss[0])


def test_within_zero_radius_at_stored_point(ds, oracle):
    """dist=0 centered on a hotel's f32-stored point: the MBR prune layer
    must not drop what exact refinement keeps (store MBRs cover the f32
    pool geometry, not just the caller's f64 boxes)."""
    store = ds.store
    ns = ds.ns
    # find a hotel entity and its stored first point
    hotel_rows = store.scan(p=ns["rdf:type"], o=ns["class:hotel"])
    ent = int(hotel_rows[0, 1])
    row = int(store.geom_rows(np.array([ent]))[0])
    p = store.geom_pool.points[store.geom_pool.offsets[row]].astype(
        np.float64)
    q = _shape_query(ds, SpatialFilter(Var("g1"), None, dist=0.0,
                                       center=(float(p[0]), float(p[1]))))
    es, erows, _ = _assert_identical(StreakEngine(ds.store), oracle, q)
    assert erows.n > 0
    assert np.all(es == 0.0)
    assert ent in set(np.unique(erows["place"]).tolist())


def test_mbr_join_zero_area_boxes_zero_dist():
    """Zero-area driver/driven MBRs at the same location join at dist 0 on
    every backend."""
    pt = np.array([[0.25, 0.5, 0.25, 0.5]])
    other = np.array([[0.25, 0.5, 0.25, 0.5], [0.7, 0.7, 0.7, 0.7]])
    for backend in ("numpy", "kernel", "fused"):
        i, j = spatial_join.mbr_distance_join(pt, other, 0.0, backend)
        assert i.tolist() == [0] and j.tolist() == [0], backend


# ------------------------------------- S1: compressed E-list rank mapping --
def test_packed_elist_ranks_of_reports_gaps():
    from repro.core.squadtree import PackedEList
    # nodes 0..4; only nodes 1 and 3 have E-lists
    offsets = np.array([0, 0, 2, 2, 5, 5], dtype=np.int64)
    ids = np.array([10, 30, 20, 40, 50], dtype=np.int64)
    obj_ids = np.array([10, 20, 30, 40, 50], dtype=np.int64)
    p = PackedEList.encode(offsets, ids, obj_ids)
    ranks, pos = p.ranks_of(np.arange(5, dtype=np.int64))
    assert pos.tolist() == [1, 3]           # empty nodes are visible gaps
    assert p.decode(ranks[:1]).tolist() == [10, 30]
    assert p.decode(ranks[1:]).tolist() == [20, 40, 50]
    # all-empty request
    ranks0, pos0 = p.ranks_of(np.array([0, 2, 4], dtype=np.int64))
    assert len(ranks0) == 0 and len(pos0) == 0


def test_packed_elist_tree_matches_uncompressed():
    """filter_material and per-node elist through the packed tier agree
    with the raw CSR tier on a tree whose nodes mix empty and nonempty
    E-lists (the silent-drop regression: a query touching an empty-E-list
    node must not misalign the decoded lists of its neighbors)."""
    import copy

    from repro.core.squadtree import build
    rng = np.random.default_rng(0)
    n = 300
    pts = rng.uniform(0.0, 100.0, (n, 2))
    # half the objects get wide boxes so they settle on INTERNAL nodes
    # (nonempty E-lists there), half are points (leaf-level)
    w = np.where(np.arange(n) % 2 == 0, 8.0, 0.0)[:, None]
    boxes = np.concatenate([pts - w, pts + w], axis=1)
    keys = np.arange(1, n + 1, dtype=np.int64) * 7
    cs = np.zeros(n, dtype=np.int64)
    raw = build(keys, boxes, cs, l_max=6, leaf_capacity=8)
    packed = copy.deepcopy(raw).pack_elists()
    assert packed.packed is not None
    sizes = raw.elist_offsets[1:] - raw.elist_offsets[:-1]
    assert (sizes == 0).any() and (sizes > 0).any()   # mixed, by design
    for node in range(len(raw.node_z)):
        np.testing.assert_array_equal(raw.elist(node), packed.elist(node))
    every = np.arange(len(raw.node_z), dtype=np.int64)
    iv_r, ex_r = raw.filter_material(every)
    iv_p, ex_p = packed.filter_material(every)
    np.testing.assert_array_equal(np.sort(ex_r), np.sort(ex_p))
    np.testing.assert_array_equal(iv_r, iv_p)


# ----------------------------------------------------- serve-loop adapter --
def test_shapes_through_serve_loop_match_serial(ds, oracle):
    from repro.serve.spatial import SpatialServeEngine
    queries = [_shape_query(ds, SHAPES[n])
               for n in ("range", "within", "join", "knn4")]
    queries.append(ds.queries[0])           # a top-k companion tenant
    srv = SpatialServeEngine(ds.store, ExecConfig(), max_slots=3)
    reqs = srv.serve(queries)
    eng = StreakEngine(ds.store)
    for req, q in zip(reqs, queries):
        assert req.error is None
        want_s, want_r, _ = eng.execute(q)
        np.testing.assert_array_equal(req.scores, want_s)
        for c in want_r.keys():
            np.testing.assert_array_equal(req.rows[c], want_r[c])
