"""SASRec: self-attentive sequential recommendation [arXiv:1808.09781].

Item embedding table (the recsys hot path: lookup = jnp.take; bag-style
multi-hot features would use take + segment_sum) -> learned positional
embedding -> `n_blocks` causal single-head transformer blocks -> dot-product
scoring against item embeddings.

Serving integrates the paper's technique end-to-end: `retrieval_cand`
(1 query x 10^6 candidates) and `serve_bulk` run through STREAK's block-wise
top-k with threshold early termination (serve/retrieval.py), i.e. the
ORDER BY ... LIMIT machinery minus the spatial filter.

Sharding: item table row-shards over "model" (vocab parallelism); batch over
("pod","data").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers
from .layers import dense_init, layer_norm


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    d_ff: int = 50
    dropout: float = 0.2
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 2 * d * self.d_ff
        return (self.n_items + self.seq_len) * d + self.n_blocks * per_block


def init_params(key, cfg: SASRecConfig):
    dt = cfg.jdtype
    d = cfg.embed_dim
    ks = layers.split_keys(key, 2 + 6 * cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        kq, kk, kv, ko, k1, k2 = jax.random.split(ks[2 + i], 6)
        blocks.append({
            "wq": dense_init(kq, (d, d), dtype=dt),
            "wk": dense_init(kk, (d, d), dtype=dt),
            "wv": dense_init(kv, (d, d), dtype=dt),
            "wo": dense_init(ko, (d, d), dtype=dt),
            "w1": dense_init(k1, (d, cfg.d_ff), dtype=dt),
            "w2": dense_init(k2, (cfg.d_ff, d), dtype=dt),
            "ln1_scale": jnp.ones((d,), dt), "ln1_bias": jnp.zeros((d,), dt),
            "ln2_scale": jnp.ones((d,), dt), "ln2_bias": jnp.zeros((d,), dt),
        })
    return {
        "item_embed": dense_init(ks[0], (cfg.n_items, d), in_axis=1, dtype=dt),
        "pos_embed": dense_init(ks[1], (cfg.seq_len, d), in_axis=1, dtype=dt),
        "blocks": blocks,
    }


def encode(params, seq: jnp.ndarray, cfg: SASRecConfig) -> jnp.ndarray:
    """seq (B, S) int32 item ids (0 = padding) -> user states (B, S, D)."""
    b, s = seq.shape
    d = cfg.embed_dim
    x = params["item_embed"][seq] * (d ** 0.5) + params["pos_embed"][None, :s]
    pad = (seq == 0)
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    mask = causal[None] & ~pad[:, None, :]
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_scale"], blk["ln1_bias"])
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * (d ** -0.5)
        logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        x = x + (jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
                 .astype(x.dtype)) @ blk["wo"]
        h = layer_norm(x, blk["ln2_scale"], blk["ln2_bias"])
        x = x + jax.nn.relu(h @ blk["w1"]) @ blk["w2"]
    return x


def user_state(params, seq: jnp.ndarray, cfg: SASRecConfig) -> jnp.ndarray:
    """Last-position state (B, D)."""
    return encode(params, seq, cfg)[:, -1, :]


def score_candidates(params, state: jnp.ndarray,
                     candidates: jnp.ndarray) -> jnp.ndarray:
    """state (B, D) x candidates (B, C) item ids -> (B, C) scores."""
    emb = params["item_embed"][candidates]            # (B, C, D)
    return jnp.einsum("bd,bcd->bc", state, emb)


def score_all(params, state: jnp.ndarray) -> jnp.ndarray:
    """Full-catalog scores (B, N_items) — offline bulk scoring path."""
    return state @ params["item_embed"].T


def bpr_loss(params, seq, pos_items, neg_items, cfg: SASRecConfig):
    """Sequence-to-sequence BPR: predict item t+1 at every position."""
    states = encode(params, seq, cfg)                  # (B, S, D)
    pe = params["item_embed"][pos_items]               # (B, S, D)
    ne = params["item_embed"][neg_items]
    pos_s = jnp.sum(states * pe, axis=-1)
    neg_s = jnp.sum(states * ne, axis=-1)
    valid = (pos_items != 0).astype(jnp.float32)
    ll = jax.nn.log_sigmoid(pos_s - neg_s) * valid
    return -ll.sum() / jnp.maximum(valid.sum(), 1.0)
