"""Block-wise top-k accumulation with threshold early termination (§3.3).

The TopK state is the piece both N-Plan and S-Plan share: because the heap and
threshold θ survive across blocks and plans, switching plans at a
materialization point costs nothing (the paper's "zero plan-switch cost").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .join import Relation

NEG_INF = -np.inf


@dataclasses.dataclass
class TopK:
    k: int
    descending: bool = True
    scores: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=np.float64))
    rows: Relation = dataclasses.field(default_factory=Relation)

    def _key(self, s: np.ndarray) -> np.ndarray:
        return s if self.descending else -s

    @property
    def theta(self) -> float:
        """Score of the k-th result so far; -inf until the heap is full.

        (In ascending mode this is reported in *key space*: compare with
        `key(score) > theta` to test if a candidate can still enter.)
        """
        if len(self.scores) < self.k:
            return NEG_INF
        return float(self._key(self.scores).min())

    @property
    def full(self) -> bool:
        return len(self.scores) >= self.k

    def push(self, scores: np.ndarray, rows: Relation) -> None:
        if len(scores) == 0:
            return
        if self.rows.n == 0 and rows.n > 0:
            self.rows = Relation({c: np.empty(0, dtype=v.dtype)
                                  for c, v in rows.items()})
        all_scores = np.concatenate([self.scores, scores])
        all_rows = Relation({c: np.concatenate([self.rows[c], rows[c]])
                             for c in rows})
        order = np.argsort(-self._key(all_scores), kind="stable")[: self.k]
        self.scores = all_scores[order]
        self.rows = all_rows.take(order)

    def results(self) -> tuple[np.ndarray, Relation]:
        order = np.argsort(-self._key(self.scores), kind="stable")
        return self.scores[order], self.rows.take(order)

    def can_improve(self, upper_bound: float) -> bool:
        """Could a candidate with this score bound still enter the top-k?"""
        return (not self.full) or (self._keyf(upper_bound) > self.theta)

    def _keyf(self, s: float) -> float:
        return s if self.descending else -s
