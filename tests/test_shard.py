"""Morton-prefix sharded store: shard-count invariance and the
compressed E-list tier.

The contract under test is exactness: for EVERY shard count the sharded
engine's Phases 1-2 (per-shard candidate search + V* selection with the
global θ read between shard passes) must partition the single-host work,
so results — rows, scores, and the anytime `ExecStats` fields under
deadlines — are bit-identical to the unsharded engine, not merely
equivalent. CI's shardlane job runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the fused
descent actually lays shards over an 8-device mesh.
"""
import copy

import numpy as np
import pytest

from repro import BackendPolicy, ExecConfig, StreakEngine
from repro.core.fault import QueryDeadline
from repro.core.shard import ShardedQuadStore, shard_store, shard_views
from repro.core.squadtree import PackedEList
from repro.data.synth_rdf import make_lgd, make_scale

SHARD_COUNTS = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def ds():
    return make_lgd(n_per_class=400, seed=1, block=256)


@pytest.fixture(scope="module")
def sharded(ds):
    return {n: shard_store(ds.store, n) for n in SHARD_COUNTS}


# ------------------------------------------------------------- partition ---
def test_shards_partition_object_space(ds, sharded):
    """Shard object ranges are disjoint, ordered, and cover obj_ids."""
    for n, st in sharded.items():
        assert isinstance(st, ShardedQuadStore)
        assert st.n_shards == n
        cat = np.concatenate([sh.tree.obj_ids for sh in st.tree_shards])
        np.testing.assert_array_equal(cat, ds.store.tree.obj_ids)
        for sh in st.tree_shards:
            assert sh.id_lo == sh.tree.obj_ids[0]
            assert sh.id_hi == sh.tree.obj_ids[-1]
        los = [sh.id_lo for sh in st.tree_shards]
        his = [sh.id_hi for sh in st.tree_shards]
        assert all(h < l for h, l in zip(his[:-1], los[1:]))


def test_shard_views_unsharded_is_single_noclip(ds):
    views = shard_views(ds.store)
    assert len(views) == 1 and not views[0].clip
    assert views[0].tree is ds.store.tree


# ------------------------------------------- shard-count invariance --------
_POLICIES = {
    "numpy": ExecConfig(),
    "fused": ExecConfig(policy=BackendPolicy(join="fused", kcap="auto")),
}


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("cname", sorted(_POLICIES))
def test_results_bit_identical_across_shard_counts(ds, sharded, n_shards,
                                                   cname):
    cfg = _POLICIES[cname]
    eng0 = StreakEngine(ds.store, cfg)
    eng1 = StreakEngine(sharded[n_shards], cfg)
    for q in ds.queries:
        s0, r0, st0 = eng0.execute(q)
        s1, r1, st1 = eng1.execute(q)
        np.testing.assert_array_equal(s1, s0)
        assert r1.keys() == r0.keys()
        for c in r0:
            np.testing.assert_array_equal(r1[c], r0[c])
        assert st1.partial == st0.partial
        assert st1.score_bound == st0.score_bound


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_deadline_anytime_answer_invariant(ds, sharded, n_shards):
    """A block-budget deadline truncates the driver scan at the same block
    on every shard count, so the partial answer AND its certified bound
    must match the unsharded cursor exactly."""
    eng0 = StreakEngine(ds.store, ExecConfig())
    eng1 = StreakEngine(sharded[n_shards], ExecConfig())
    hit_partial = False
    for q in ds.queries:
        for blocks in (1, 2):
            dl = QueryDeadline(max_blocks=blocks)
            s0, r0, st0 = eng0.execute(q, deadline=dl)
            s1, r1, st1 = eng1.execute(q, deadline=dl)
            np.testing.assert_array_equal(s1, s0)
            assert r1.n == r0.n
            assert st1.partial == st0.partial
            assert st1.deadline_expired == st0.deadline_expired
            assert st1.score_bound == st0.score_bound
            hit_partial |= st0.partial
    assert hit_partial, "deadline never truncated: test is vacuous"


@pytest.mark.parametrize("n_shards", (2, 8))
def test_serve_loop_matches_serial_on_sharded_store(ds, sharded, n_shards):
    from repro.serve.spatial import SpatialServeEngine
    cfg = ExecConfig(policy=BackendPolicy(join="fused", kcap="auto"))
    serial = [StreakEngine(ds.store, cfg).execute(q) for q in ds.queries[:4]]
    srv = SpatialServeEngine(sharded[n_shards], cfg, max_slots=4)
    reqs = srv.serve(list(ds.queries[:4]))
    for req, (scores, rows, _) in zip(reqs, serial):
        assert req.done and req.error is None
        np.testing.assert_array_equal(req.scores, scores)
        assert req.rows.n == rows.n


def test_sip_disabled_collapses_to_whole_view(ds, sharded):
    """With SIP off there is no interval clip, so the cursor must fall
    back to ONE global view — and still match the unsharded engine."""
    cfg = ExecConfig(use_sip=False)
    eng0 = StreakEngine(ds.store, cfg)
    eng1 = StreakEngine(sharded[4], cfg)
    q = ds.queries[0]
    cur = eng1.cursor(q)
    assert len(cur.shards) == 1 and not cur.shards[0].clip
    s0, r0, _ = eng0.execute(q)
    s1, r1, _ = eng1.execute(q)
    np.testing.assert_array_equal(s1, s0)
    assert r1.n == r0.n


# ------------------------------------------------- compressed E-list tier --
def test_packed_elist_roundtrip(ds):
    tree = ds.store.tree
    ref_ids = tree.elist_ids.copy()
    ref_off = tree.elist_offsets
    t2 = copy.copy(tree)
    t2.elist_ids = ref_ids.copy()
    t2.packed = None
    t2.pack_elists()
    pk = t2.packed
    assert pk.src is not None, "tree-owned ids must pack in rank mode"
    np.testing.assert_array_equal(pk.decode(np.arange(len(pk.nodes))),
                                  ref_ids)
    rng = np.random.default_rng(0)
    sub = rng.permutation(len(pk.nodes))[:25]
    want = np.concatenate([ref_ids[ref_off[n]:ref_off[n + 1]]
                           for n in pk.nodes[sub]])
    np.testing.assert_array_equal(pk.decode(sub), want)
    for node in pk.nodes[:64]:
        a, b = ref_off[node], ref_off[node + 1]
        np.testing.assert_array_equal(t2.elist(int(node)), ref_ids[a:b])
        assert t2.elist_size(int(node)) == b - a


def test_packed_elist_raw_fallback():
    """Ids absent from the src array must fall back to raw-id gap packing
    and still decode exactly."""
    offsets = np.array([0, 3, 3, 7], dtype=np.int64)
    ids = np.array([10, 1 << 40, (1 << 40) + 5,
                    7, 9, 1 << 50, (1 << 50) + 1], dtype=np.int64)
    src = np.array([1, 2, 3], dtype=np.int64)      # contains none of them
    pk = PackedEList.encode(offsets, ids, src)
    assert pk.src is None
    np.testing.assert_array_equal(pk.decode(np.arange(len(pk.nodes))), ids)


def test_compressed_tier_halves_elist_bytes():
    """Acceptance: the packed tier must cut per-shard E-list bytes >=2x on
    a scale-generator store, with results unchanged vs the plain tier."""
    ds = make_scale(200_000, seed=0)
    plain = shard_store(ds.store, 4, compressed=False)
    packed = shard_store(ds.store, 4, compressed=True)
    packed_b = sum(sh.tree.packed.nbytes() for sh in packed.tree_shards)
    plain_b = sum(sh.tree.elist_ids.nbytes for sh in plain.tree_shards)
    assert plain_b >= 2 * packed_b, (plain_b, packed_b)
    assert packed.shard_tree_nbytes() < plain.shard_tree_nbytes()
    e0 = StreakEngine(plain, ExecConfig())
    e1 = StreakEngine(packed, ExecConfig())
    for q in ds.queries:
        s0, r0, _ = e0.execute(q)
        s1, r1, _ = e1.execute(q)
        np.testing.assert_array_equal(s1, s0)
        assert r1.n == r0.n
