"""Architecture registry: the 10 assigned archs + the paper's own workloads.

Every entry carries the EXACT published config [source; verification tier in
the arch module docstring], its shape set, and a reduced smoke config.
"""
from __future__ import annotations

import importlib

ARCHS = {
    # LM-family (shapes: train_4k / prefill_32k / decode_32k / long_500k)
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    # GNN (shapes: full_graph_sm / minibatch_lg / ogb_products / molecule)
    "gcn-cora": "repro.configs.gcn_cora",
    "graphcast": "repro.configs.graphcast_cfg",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "nequip": "repro.configs.nequip_cfg",
    # recsys (train_batch / serve_p99 / serve_bulk / retrieval_cand)
    "sasrec": "repro.configs.sasrec_cfg",
}

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(kind="sampled", n_nodes=232965, n_edges=114615892,
                         batch_nodes=1024, fanout=(15, 10), d_feat=602,
                         n_classes=41),
    "ogb_products": dict(kind="full", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="batched", n_nodes=30, n_edges=64, batch=128,
                     d_feat=32, n_classes=1),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="bulk", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


def get(arch_id: str):
    mod = importlib.import_module(ARCHS[arch_id])
    return mod


def all_cells() -> list:
    """All 40 (arch, shape) cells."""
    out = []
    for arch_id in ARCHS:
        mod = get(arch_id)
        for shape in mod.SHAPES:
            out.append((arch_id, shape))
    return out
