"""Fig. 7: effect of sideways information passing + node selection.

Per benchmark query: execution time with SIP on vs off (fixed S-Plan so the
only difference is the I-Range/E-list filtering), plus driven rows scanned.
Expected pattern (paper §5.1.1): large wins on spatially selective queries,
little effect on low-selectivity ones.
"""
from __future__ import annotations

from repro.core.executor import ExecConfig, StreakEngine

from . import common


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            eng_on = StreakEngine(ds.store, ExecConfig(force_plan="S"))
            eng_off = StreakEngine(ds.store,
                                   ExecConfig(force_plan="S", use_sip=False))
            t_on = common.timeit(lambda: eng_on.execute(q))
            t_off = common.timeit(lambda: eng_off.execute(q))
            _, _, s_on = eng_on.execute(q)
            _, _, s_off = eng_off.execute(q)
            rows.append(common.row(
                f"fig7_sip/{ds_name}/Q{qi+1}_on", t_on,
                f"join_rows={s_on.driven_rows_after_sip};"
                f"pairs={s_on.join.pairs_tested}"))
            rows.append(common.row(
                f"fig7_sip/{ds_name}/Q{qi+1}_off", t_off,
                f"join_rows={s_off.driven_rows_after_sip};"
                f"pairs={s_off.join.pairs_tested};"
                f"speedup={t_off/max(t_on,1):.2f}x"))
    return rows
