"""Message-passing GNNs: GCN [Kipf'16] and GraphSAGE [Hamilton'17].

JAX has no CSR sparse — message passing IS `jnp.take` (gather by src) +
`jax.ops.segment_sum` (scatter by dst), which is the system's own
embedding-bag/SpMM substrate (kernel_taxonomy §GNN). Graphs are edge lists
(2, E) int32; degree normalization coefficients are precomputed per edge for
GCN's symmetric normalization.

Sharding: node features row-shard over "data"; edge arrays shard over
"data"; weight matrices replicate (d_hidden 16..128 is far below the TP
threshold) except the large ogb_products input projection which column-shards
over "model".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers
from .layers import dense_init


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    arch: str = "gcn"              # gcn | sage
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    d_out: int = 7
    aggregator: str = "mean"       # mean | sum | max
    dropout: float = 0.0
    sample_sizes: tuple = (25, 10)  # GraphSAGE fanouts
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        dims = [self.d_in] + [self.d_hidden] * (self.n_layers - 1) + [self.d_out]
        mult = 2 if self.arch == "sage" else 1
        return sum(mult * a * b for a, b in zip(dims[:-1], dims[1:]))


def init_params(key, cfg: GNNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    ks = layers.split_keys(key, 2 * cfg.n_layers)
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p = {"w": dense_init(ks[2 * i], (a, b), dtype=cfg.jdtype)}
        if cfg.arch == "sage":
            p["w_self"] = dense_init(ks[2 * i + 1], (a, b), dtype=cfg.jdtype)
        params.append(p)
    return {"layers": params}


def _aggregate(msg: jnp.ndarray, dst: jnp.ndarray, n: int, kind: str):
    if kind == "sum":
        return jax.ops.segment_sum(msg, dst, num_segments=n)
    if kind == "mean":
        s = jax.ops.segment_sum(msg, dst, num_segments=n)
        c = jax.ops.segment_sum(jnp.ones((msg.shape[0], 1), msg.dtype), dst,
                                num_segments=n)
        return s / jnp.maximum(c, 1.0)
    if kind == "max":
        return jax.ops.segment_max(msg, dst, num_segments=n)
    raise ValueError(kind)


def forward(params, x: jnp.ndarray, edges: jnp.ndarray, cfg: GNNConfig,
            edge_norm: jnp.ndarray | None = None):
    """x (N, F); edges (2, E) [src, dst] -> logits (N, d_out).

    For GCN pass edge_norm = deg(src)^-1/2 * deg(dst)^-1/2 per edge (or None
    to compute it on the fly).
    """
    src, dst = edges[0], edges[1]
    n = x.shape[0]
    deg = None
    if cfg.arch == "gcn":
        # D-tilde = deg + 1 (self loop); sym norm 1/sqrt(d_i d_j) per edge
        deg = jax.ops.segment_sum(jnp.ones_like(src, dtype=x.dtype), dst,
                                  num_segments=n) + 1.0
        if edge_norm is None:
            edge_norm = jax.lax.rsqrt(deg[src]) * jax.lax.rsqrt(deg[dst])
    h = x
    for i, lp in enumerate(params["layers"]):
        msg = h[src]
        if cfg.arch == "gcn":
            agg = _aggregate(msg * edge_norm[:, None], dst, n, "sum")
            agg = agg + h / deg[:, None]          # the A+I self-loop term
            h = agg @ lp["w"]
        else:  # sage
            agg = _aggregate(msg, dst, n, cfg.aggregator)
            h = agg @ lp["w"] + h @ lp["w_self"]
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


def nll_loss(params, x, edges, labels, mask, cfg: GNNConfig,
             edge_norm=None):
    logits = forward(params, x, edges, cfg, edge_norm).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    gold = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    m = mask.astype(jnp.float32)
    return -(gold * m).sum() / jnp.maximum(m.sum(), 1.0)
