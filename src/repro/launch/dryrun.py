import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) cell on the single-pod
(16, 16) mesh and the 2-pod (2, 16, 16) mesh using 512 placeholder host
devices, prints memory_analysis / cost_analysis, extracts per-collective
byte counts from the optimized HLO, and dumps one JSON per cell into
artifacts/dryrun/ for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from ..configs import registry
from ..roofline import hlo_parse
from .cells import build_cell
from .mesh import make_production_mesh

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool,
             verbose: bool = True, variant: str = "base") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, variant=variant)
    with mesh:
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        if cell.donate and variant != "base":
            kw["donate_argnums"] = cell.donate
        jitted = jax.jit(cell.fn, **kw)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
    colls = hlo_parse.collective_bytes(text)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "compile_s": round(time.time() - t0, 1),
        # memory_analysis is per-device
        "arg_bytes": int(mem.argument_size_in_bytes),
        "out_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        # cost_analysis is per-device BUT counts while bodies once; the
        # loop-weighted hlo_parse numbers below are the roofline inputs
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "dot_flops_per_device": hlo_parse.dot_flops(text),
        "hbm_bytes_per_device": hlo_parse.hbm_bytes(text),
        "collectives": colls,
    }
    if verbose:
        peak = rec["arg_bytes"] + rec["temp_bytes"] + rec["out_bytes"]
        print(f"[{arch} x {shape} x {rec['mesh']}] compiled in "
              f"{rec['compile_s']}s; per-device: args "
              f"{rec['arg_bytes']/2**30:.2f} GiB, temps "
              f"{rec['temp_bytes']/2**30:.2f} GiB, peak ~{peak/2**30:.2f} GiB;"
              f" flops {rec['flops_per_device']:.3e}; collective bytes "
              f"{sum(c['bytes'] for c in colls.values()):.3e}")
    ART.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    out = ART / f"{arch}__{shape}__{rec['mesh']}{suffix}.json"
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="both")
    ap.add_argument("--variant", default="base", choices=["base", "opt", "opt2"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = registry.all_cells() if args.all else None
    if cells is None:
        archs = [args.arch] if args.arch else list(registry.ARCHS)
        cells = []
        for a in archs:
            shapes = [args.shape] if args.shape else list(registry.get(a).SHAPES)
            cells += [(a, s) for s in shapes]

    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    failures = []
    suffix = "" if args.variant == "base" else f"__{args.variant}"
    for arch, shape in cells:
        for mp in pods:
            name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}{suffix}"
            if args.skip_existing and (ART / f"{name}.json").exists():
                print(f"[skip] {name}")
                continue
            try:
                run_cell(arch, shape, mp, variant=args.variant)
            except Exception:
                failures.append(name)
                print(f"[FAIL] {name}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
