"""Geometry primitives: extents, MBRs, distances.

All boxes are ``(xmin, ymin, xmax, ymax)`` float64 rows. World coordinates are
normalized into the unit square via :class:`Extent` before indexing, so the
quadtree / Z-order machinery only ever sees ``[0, 1)^2``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

try:  # jnp versions used on the jitted query path
    import jax.numpy as jnp
except Exception:  # pragma: no cover - jax is a hard dep in practice
    jnp = None

EARTH_RADIUS_KM = 6371.0088


@dataclasses.dataclass(frozen=True)
class Extent:
    """World bounding box with normalization helpers."""

    xmin: float
    ymin: float
    xmax: float
    ymax: float

    @staticmethod
    def of(boxes: np.ndarray, pad: float = 1e-9) -> "Extent":
        boxes = np.asarray(boxes, dtype=np.float64)
        span_x = float(boxes[:, 2].max() - boxes[:, 0].min())
        span_y = float(boxes[:, 3].max() - boxes[:, 1].min())
        # pad so that max coordinate maps strictly inside [0, 1)
        px = max(span_x, 1e-12) * pad + 1e-12
        py = max(span_y, 1e-12) * pad + 1e-12
        return Extent(
            float(boxes[:, 0].min()), float(boxes[:, 1].min()),
            float(boxes[:, 2].max()) + px, float(boxes[:, 3].max()) + py,
        )

    @property
    def width(self) -> float:
        return self.xmax - self.xmin

    @property
    def height(self) -> float:
        return self.ymax - self.ymin

    def normalize(self, boxes: np.ndarray) -> np.ndarray:
        boxes = np.asarray(boxes, dtype=np.float64)
        out = np.empty_like(boxes)
        out[:, 0] = (boxes[:, 0] - self.xmin) / self.width
        out[:, 2] = (boxes[:, 2] - self.xmin) / self.width
        out[:, 1] = (boxes[:, 1] - self.ymin) / self.height
        out[:, 3] = (boxes[:, 3] - self.ymin) / self.height
        return np.clip(out, 0.0, np.nextafter(1.0, 0.0))

    def denormalize_distance(self, d_world: float) -> float:
        """World distance -> normalized-space distance (isotropic approx).

        The spatial filter ``distance(a, b) < d`` is evaluated in world units
        during refinement; the normalized distance is only used for
        conservative MBR pruning, so we take the *smaller* scale to stay
        safe: normalization is anisotropic (x / width, y / height), and a
        world distance d spans up to d / min(width, height) in normalized
        space. Dividing by the larger span under-covers the other axis and
        prunes qualifying boundary pairs (caught by the differential query
        fuzzer on anisotropic extents).
        """
        return d_world / min(self.width, self.height)


def point_boxes(xy: np.ndarray) -> np.ndarray:
    """Degenerate MBRs for point data, shape (n, 2) -> (n, 4)."""
    xy = np.asarray(xy, dtype=np.float64)
    return np.concatenate([xy, xy], axis=1)


def boxes_intersect(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise-broadcast box intersection test. a: (..., 4), b: (..., 4)."""
    return (
        (a[..., 0] <= b[..., 2]) & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3]) & (b[..., 1] <= a[..., 3])
    )


def expand_boxes(boxes: np.ndarray, d: float) -> np.ndarray:
    out = np.array(boxes, dtype=np.float64, copy=True)
    out[..., 0] -= d
    out[..., 1] -= d
    out[..., 2] += d
    out[..., 3] += d
    return out


def union_boxes(boxes: np.ndarray) -> np.ndarray:
    """Union MBR over the leading axis; returns (4,)."""
    return np.array([
        boxes[:, 0].min(), boxes[:, 1].min(),
        boxes[:, 2].max(), boxes[:, 3].max(),
    ])


def clip_boxes(boxes: np.ndarray, cell: np.ndarray) -> np.ndarray:
    out = np.array(boxes, dtype=np.float64, copy=True)
    out[..., 0] = np.maximum(out[..., 0], cell[0])
    out[..., 1] = np.maximum(out[..., 1], cell[1])
    out[..., 2] = np.minimum(out[..., 2], cell[2])
    out[..., 3] = np.minimum(out[..., 3], cell[3])
    return out


def box_min_dist(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Minimum euclidean distance between two boxes (0 when intersecting)."""
    dx = np.maximum(0.0, np.maximum(a[..., 0] - b[..., 2], b[..., 0] - a[..., 2]))
    dy = np.maximum(0.0, np.maximum(a[..., 1] - b[..., 3], b[..., 1] - a[..., 3]))
    return np.sqrt(dx * dx + dy * dy)


def centroids(boxes: np.ndarray) -> np.ndarray:
    return np.stack(
        [(boxes[..., 0] + boxes[..., 2]) * 0.5, (boxes[..., 1] + boxes[..., 3]) * 0.5],
        axis=-1,
    )


def euclid_dist(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    d = p - q
    return np.sqrt((d * d).sum(axis=-1))


def haversine_km(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Great-circle distance in km; p, q are (..., 2) [lon, lat] degrees."""
    lon1, lat1 = np.radians(p[..., 0]), np.radians(p[..., 1])
    lon2, lat2 = np.radians(q[..., 0]), np.radians(q[..., 1])
    dlat = lat2 - lat1
    dlon = lon2 - lon1
    h = np.sin(dlat / 2.0) ** 2 + np.cos(lat1) * np.cos(lat2) * np.sin(dlon / 2.0) ** 2
    return 2.0 * EARTH_RADIUS_KM * np.arcsin(np.sqrt(np.clip(h, 0.0, 1.0)))


# ----------------------------------------------------------------------------
# jnp twins used inside jitted query operators
# ----------------------------------------------------------------------------

def jnp_box_min_dist(a, b):
    dx = jnp.maximum(0.0, jnp.maximum(a[..., 0] - b[..., 2], b[..., 0] - a[..., 2]))
    dy = jnp.maximum(0.0, jnp.maximum(a[..., 1] - b[..., 3], b[..., 1] - a[..., 3]))
    return jnp.sqrt(dx * dx + dy * dy)


def jnp_euclid_dist(p, q):
    d = p - q
    return jnp.sqrt((d * d).sum(axis=-1))


def jnp_haversine_km(p, q):
    lon1, lat1 = jnp.radians(p[..., 0]), jnp.radians(p[..., 1])
    lon2, lat2 = jnp.radians(q[..., 0]), jnp.radians(q[..., 1])
    dlat = lat2 - lat1
    dlon = lon2 - lon1
    h = jnp.sin(dlat / 2.0) ** 2 + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin(dlon / 2.0) ** 2
    return 2.0 * EARTH_RADIUS_KM * jnp.arcsin(jnp.sqrt(jnp.clip(h, 0.0, 1.0)))
