"""STREAK block-wise query execution (paper Figure 5).

Driver bindings are retrieved in score-key order (blocks), each block is
SIP-filtered against the S-QuadTree (Phases 1+2), routed through the APS
decision (N-Plan vs S-Plan) for driven retrieval, spatially joined (Phase 3),
refined, scored, and pushed into the shared top-k state. Early termination
fires when the best possible remaining score key cannot beat theta.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import aps, node_select, spatial_join
from .join import Relation, filter_in_ranges, join, scan_pattern
from .planner import QueryPlan, SidePlan, plan_query
from .query import Query, Var
from .spatial_join import JoinStats
from .store import DirectedNumericScan, QuadStore
from .topk import TopK


@dataclasses.dataclass
class ExecConfig:
    block: int = 1024
    use_sip: bool = True
    force_plan: str | None = None       # "N" | "S" | None (adaptive)
    force_driver: str | None = None     # "a" | "b" | None
    join_backend: str = "numpy"         # "numpy" | "kernel" | "fused"
    join_impl: str | None = None        # core/join.JOIN_IMPLS; None = auto
    #                                     ("merge", the jitted two-phase core)
    fused_batch_cols: int = 4096        # driven columns per fused-kernel call
    refine_chunk: int = 1024            # candidate pairs refined per θ check
    sip_lookahead: int = 8              # driver blocks per batched SIP call
    probe_backend: str | None = None    # charsets.PROBE_BACKENDS; None = auto
    mbr_join_fn: object = None          # override Phase-3 MBR join (baselines)
    select_params: node_select.SelectParams = dataclasses.field(
        default_factory=node_select.SelectParams)
    cost_params: aps.CostParams = dataclasses.field(
        default_factory=aps.CostParams)


@dataclasses.dataclass
class ExecStats:
    driver_blocks: int = 0
    plan_n: int = 0
    plan_s: int = 0
    driven_rows_scanned: int = 0
    driven_rows_after_sip: int = 0
    results_considered: int = 0
    early_terminated: bool = False
    v_star_sizes: list = dataclasses.field(default_factory=list)
    join: JoinStats = dataclasses.field(default_factory=JoinStats)
    plan_log: list = dataclasses.field(default_factory=list)


class StreakEngine:
    def __init__(self, store: QuadStore, config: ExecConfig | None = None):
        self.store = store
        self.config = config or ExecConfig()
        self._scan_cache: dict = {}

    # ------------------------------------------------------------------
    def _cached_scan(self, tp) -> Relation:
        key = (tp.g, tp.s, tp.p, tp.o)
        if key not in self._scan_cache:
            self._scan_cache[key] = scan_pattern(self.store, tp)
        return self._scan_cache[key]

    def _join_chain(self, base: Relation, patterns: list,
                    impl: str | None = None) -> Relation:
        rel = base
        for tp in patterns:
            if rel.n == 0:
                break
            rel = join(rel, self._cached_scan(tp), impl=impl)
        return rel

    def _block_relation(self, side: SidePlan, b: int) -> tuple[Relation, np.ndarray]:
        """Relation for one primary-scan block + its score-key values."""
        vals, subj, obj, facts = side.scan.get_block(b)
        tp = side.primary[0]
        rel = Relation()
        if isinstance(tp.s, Var):
            rel[tp.s.name] = subj
        if isinstance(tp.o, Var):
            rel[tp.o.name] = obj
        if isinstance(tp.g, Var):
            rel[tp.g.name] = facts
        return rel, vals

    # score-key weight of a term: flips sign for ascending ranking
    @staticmethod
    def _kw(weight: float, descending: bool) -> float:
        return weight if descending else -weight

    def _side_bound(self, side: SidePlan, descending: bool,
                    exclude_primary: bool) -> float:
        """Best possible score-key contribution from this side's quant terms."""
        total = 0.0
        for tp, var, w in side.quant_terms:
            if exclude_primary and side.primary is not None and tp is side.primary[0]:
                continue
            scan = DirectedNumericScan(self.store.numeric[int(tp.p)], descending)
            kw = self._kw(w, descending)
            v_best = scan.ni.block_max[0] if kw > 0 else scan.ni.block_min[-1]
            total += kw * float(v_best)
        return total

    def _score_key(self, rel: Relation, plan: QueryPlan) -> np.ndarray:
        """Score key per row = sum_i kw_i * value(?v_i)."""
        out = np.zeros(rel.n)
        for side in (plan.driver, plan.driven):
            for tp, var, w in side.quant_terms:
                kw = self._kw(w, plan.descending)
                out += kw * self.store.values_of(rel[var])
        return out

    def _entity_key_bound(self, rel: Relation, ents: np.ndarray,
                          side: SidePlan, plan: QueryPlan) -> np.ndarray:
        """Per-entity upper bound on this side's score-key contribution.

        Any result row pairing entities (e_i, e_j) joins one `rel` row per
        side, so max-over-rows per entity bounds the pair's score key from
        above — the soundness condition for the fused kernel's θ pruning.
        Rows whose contribution is NaN (entity lacks a value) can never
        score and count as -inf; an entity with only such rows gets -inf.
        """
        contrib = np.zeros(rel.n)
        for tp, var, w in side.quant_terms:
            kw = self._kw(w, plan.descending)
            contrib += kw * self.store.values_of(rel[var])
        contrib = np.where(np.isnan(contrib), -np.inf, contrib)
        out = np.full(len(ents), -np.inf)
        ent_col = rel[side.entity_var]
        pos = np.searchsorted(ents, ent_col)        # ents is sorted unique
        ok = (pos < len(ents)) & \
            (ents[np.minimum(pos, len(ents) - 1)] == ent_col)
        np.maximum.at(out, pos[ok], contrib[ok])
        return out

    def _emit_pairs(self, pi: np.ndarray, pj: np.ndarray,
                    uniq_ents: np.ndarray, dvn_ents: np.ndarray,
                    drv_rel: Relation, dvn_rel: Relation,
                    driver: SidePlan, driven: SidePlan, plan: QueryPlan,
                    topk: TopK, stats: ExecStats,
                    ds: np.ndarray | None = None,
                    vs: np.ndarray | None = None) -> None:
        """θ-aware refinement: order pairs by key bound, refine in chunks.

        Candidate pairs are sorted by descending score-key bound
        ``ds[i] + vs[j]`` (an upper bound on any result row the pair can
        produce, see `_entity_key_bound`), refined chunk-wise against the
        exact geometry pool, and survivors are scored and pushed into the
        top-k *between* chunks — so once the best remaining bound cannot
        beat θ, the whole tail of candidate pairs is skipped without ever
        touching its geometry (the paper's early termination applied to the
        refinement stage itself).
        """
        if len(pi) == 0:
            return
        store = self.store
        if ds is None:
            ds = self._entity_key_bound(drv_rel, uniq_ents, driver, plan)
        if vs is None:
            vs = self._entity_key_bound(dvn_rel, dvn_ents, driven, plan)
        bounds = ds[pi] + vs[pj]
        order = np.argsort(-bounds, kind="stable")
        pi, pj, bounds = pi[order], pj[order], bounds[order]
        # resolve pool rows once per unique entity, gather per pair
        rows_a = store.geom_rows(uniq_ents)[pi]
        rows_b = store.geom_rows(dvn_ents)[pj]
        chunk = max(int(self.config.refine_chunk), 1)
        for start in range(0, len(pi), chunk):
            # bounds are sorted: bounds[start] caps every remaining pair
            if topk.full and bounds[start] <= topk.theta:
                stats.join.refine_skipped += len(pi) - start
                break
            end = min(start + chunk, len(pi))
            keep = spatial_join.refine(
                pi[start:end], pj[start:end], store.geom_pool,
                rows_a[start:end], rows_b[start:end],
                plan.dist_world, plan.metric, stats.join)
            ci, cj = pi[start:end][keep], pj[start:end][keep]
            if len(ci) == 0:
                continue
            pair_rel = Relation({driver.entity_var: uniq_ents[ci],
                                 driven.entity_var: dvn_ents[cj]})
            out = join(drv_rel, pair_rel, impl=plan.join_impl)
            out = join(out, dvn_rel, impl=plan.join_impl)
            if out.n == 0:
                continue
            keys = self._score_key(out, plan)
            valid = ~np.isnan(keys)
            out, keys = out.take(np.flatnonzero(valid)), keys[valid]
            stats.results_considered += out.n
            topk.push(keys, out)

    # ------------------------------------------------------------------
    def execute(self, q: Query) -> tuple[np.ndarray, Relation, ExecStats]:
        cfg = self.config
        store = self.store
        tree = store.tree
        plan = plan_query(store, q, force_driver=cfg.force_driver,
                          join_impl=cfg.join_impl)
        stats = ExecStats()
        topk = TopK(k=plan.k, descending=True)  # operates in key space
        driver, driven = plan.driver, plan.driven

        driver_other = self._side_bound(driver, plan.descending, exclude_primary=True)
        driven_bound = self._side_bound(driven, plan.descending, exclude_primary=False)
        kw_p = (self._kw(driver.primary[2], plan.descending)
                if driver.primary else 0.0)
        # per-query (block-invariant) driven-CS cardinality per tree node
        card_all = tree.cs_stats.cardinality_all(plan.driven_cs)

        n_blocks = driver.scan.n_blocks if driver.scan is not None else 1
        # ---- Phases 1-2, batched over a lookahead window ----------------
        # Query-invariant probe material is hoisted here: the driven-CS keys
        # are hashed once (`prepare`) and reused by every frontier level of
        # every window. `_sip_prefetch` then runs candidate-node search +
        # node selection for `sip_lookahead` driver blocks per call, sharing
        # Bloom-row gathers and MBR tests across blocks, while the per-block
        # θ check below still terminates the scan exactly where the looped
        # path would (speculative SIP work past the cut is discarded).
        prepared = (tree.bloom_self.prepare(plan.driven_cs)
                    if cfg.use_sip else None)
        window = max(int(cfg.sip_lookahead), 1) if cfg.use_sip else 1
        pending: dict[int, tuple] = {}

        def _sip_prefetch(b0: int) -> None:
            mats = []
            for w in range(b0, min(b0 + window, n_blocks)):
                if driver.scan is not None:
                    block_rel, _ = self._block_relation(driver, w)
                    join_chain = driver.join_patterns
                else:  # no numeric driver: single full block
                    block_rel = self._cached_scan(driver.all_ordered[0])
                    join_chain = driver.all_ordered[1:]
                drv_rel = self._join_chain(block_rel, join_chain,
                                           plan.join_impl)
                uniq_ents = boxes = None
                if drv_rel.n:
                    # driver entities with geometry
                    uniq_ents = np.unique(drv_rel[driver.entity_var])
                    boxes = store.spatial_box_of(uniq_ents)
                    has_geom = ~np.isnan(boxes[:, 0])
                    uniq_ents, boxes = uniq_ents[has_geom], boxes[has_geom]
                mats.append((w, drv_rel, uniq_ents, boxes))
            if cfg.use_sip:
                box_sets = [bx if bx is not None else np.zeros((0, 4))
                            for (_, _, _, bx) in mats]
                in_v = tree.candidate_nodes(
                    box_sets, plan.dist_norm, plan.driven_cs,
                    prepared=prepared, probe_backend=cfg.probe_backend)
                v_stars = node_select.select_batch(
                    tree, in_v, plan.driven_cs, cfg.select_params, card_all)
            else:
                v_stars = [np.array([0], dtype=np.int64)] * len(mats)
            for (w, drv_rel, uniq_ents, boxes), v_star in zip(mats, v_stars):
                pending[w] = (drv_rel, uniq_ents, boxes, v_star)

        for b in range(n_blocks):
            # ---- driver block in score-key order -----------------------
            if driver.scan is not None:
                driver_primary_best = kw_p * float(driver.scan.get_block(b)[0][0])
            else:  # no numeric driver: no driver bound
                driver_primary_best = 0.0
            # ---- early termination check --------------------------------
            ub = driver_primary_best + driver_other + driven_bound
            if topk.full and ub <= topk.theta:
                stats.early_terminated = True
                break
            stats.driver_blocks += 1
            if b not in pending:
                pending.clear()
                _sip_prefetch(b)
            drv_rel, uniq_ents, boxes, v_star = pending.pop(b)
            if drv_rel.n == 0:
                continue
            if uniq_ents is None or len(uniq_ents) == 0:
                continue
            if cfg.use_sip and len(v_star) == 0:
                continue  # nothing on the driven side can join this block
            stats.v_star_sizes.append(len(v_star))
            intervals, explicit = tree.filter_material(v_star)

            # ---- APS plan decision --------------------------------------
            key_needed = (topk.theta - (driver_primary_best + driver_other)
                          - self._side_bound(driven, plan.descending, True)) \
                if topk.full else -np.inf
            decision = aps.choose(tree, v_star, plan.driven_cs, driven.scan,
                                  key_needed, drv_rel.n, cfg.cost_params,
                                  card_all)
            chosen = cfg.force_plan or decision.plan
            if driven.scan is None:
                chosen = "S"
            stats.plan_log.append(chosen)
            if chosen == "N":
                stats.plan_n += 1
                dvn_rel = self._driven_nplan(driven, plan, intervals, explicit,
                                             key_needed, stats)
            else:
                stats.plan_s += 1
                dvn_rel = self._driven_splan(driven, plan, intervals, explicit,
                                             stats)
            if dvn_rel.n == 0:
                continue

            # ---- Phase 3: spatial join + refinement ----------------------
            dvn_ents = np.unique(dvn_rel[driven.entity_var])
            dvn_boxes = store.spatial_box_of(dvn_ents)
            ok = ~np.isnan(dvn_boxes[:, 0])
            dvn_ents, dvn_boxes = dvn_ents[ok], dvn_boxes[ok]
            if len(dvn_ents) == 0:
                continue
            if cfg.mbr_join_fn is None and cfg.join_backend == "fused":
                # streaming fused path: driven columns arrive in score-key
                # order, each batch refined+scored+pushed before the next so
                # the θ the kernel prunes with tightens inside the block
                ds = self._entity_key_bound(drv_rel, uniq_ents, driver, plan)
                vs = self._entity_key_bound(dvn_rel, dvn_ents, driven, plan)
                for pi, pj in spatial_join.fused_stream_join(
                        boxes, dvn_boxes, ds, vs, plan.dist_norm, k=plan.k,
                        theta_fn=lambda: topk.theta,
                        batch_cols=cfg.fused_batch_cols, stats=stats.join):
                    self._emit_pairs(pi, pj, uniq_ents, dvn_ents, drv_rel,
                                     dvn_rel, driver, driven, plan, topk,
                                     stats, ds=ds, vs=vs)
            else:
                join_fn = cfg.mbr_join_fn or spatial_join.mbr_distance_join
                pi, pj = join_fn(boxes, dvn_boxes, plan.dist_norm,
                                 cfg.join_backend, stats.join)
                self._emit_pairs(pi, pj, uniq_ents, dvn_ents, drv_rel,
                                 dvn_rel, driver, driven, plan, topk, stats)

        keys, rows = topk.results()
        scores = keys if plan.descending else -keys
        return scores, rows, stats

    # ------------------------------------------------------------------
    def _driven_full(self, driven: SidePlan, impl: str | None) -> Relation:
        """Fully-joined driven sub-query, cached per query (S-Plan is a
        full scan per the paper; only the SIP filter varies per block)."""
        # key on the pattern *contents*: id(tp) can collide after pattern
        # objects are garbage-collected, silently reusing a stale relation
        key = ("__driven_full", impl) + tuple((tp.g, tp.s, tp.p, tp.o)
                                              for tp in driven.all_ordered)
        if key not in self._scan_cache:
            rel = self._cached_scan(driven.all_ordered[0])
            rel = self._join_chain(rel, driven.all_ordered[1:], impl)
            self._scan_cache[key] = rel
        return self._scan_cache[key]

    def _driven_splan(self, driven: SidePlan, plan: QueryPlan, intervals,
                      explicit, stats: ExecStats) -> Relation:
        """S-Plan: spatial join pushed down -- one full scan of the driven
        sub-query (cached), then I-Range/E-list skipping of its rows."""
        rel = self._driven_full(driven, plan.join_impl)
        stats.driven_rows_scanned += rel.n
        if self.config.use_sip and driven.entity_var in rel:
            rel = filter_in_ranges(rel, driven.entity_var, intervals,
                                   explicit, impl=plan.join_impl)
        stats.driven_rows_after_sip += rel.n
        return rel

    def _driven_nplan(self, driven: SidePlan, plan: QueryPlan, intervals,
                      explicit, key_needed: float, stats: ExecStats) -> Relation:
        """N-Plan: numeric predicate pushed down -- block-wise driven scan in
        score-key order with SIP skipping and threshold early termination."""
        cfg = self.config
        parts: list[Relation] = []
        kw = self._kw(driven.primary[2], plan.descending)
        for b2 in range(driven.scan.n_blocks):
            best = kw * float(driven.scan.get_block(b2)[0][0])
            if np.isfinite(key_needed) and best <= key_needed:
                break  # no further driven block can reach the threshold
            block_rel, _ = self._block_relation(driven, b2)
            stats.driven_rows_scanned += block_rel.n
            if cfg.use_sip and driven.entity_var in block_rel:
                block_rel = filter_in_ranges(block_rel, driven.entity_var,
                                             intervals, explicit,
                                             impl=plan.join_impl)
            joined = self._join_chain(block_rel, driven.join_patterns,
                                      plan.join_impl)
            if cfg.use_sip and driven.entity_var not in block_rel \
                    and driven.entity_var in joined:
                joined = filter_in_ranges(joined, driven.entity_var,
                                          intervals, explicit,
                                          impl=plan.join_impl)
            stats.driven_rows_after_sip += joined.n
            if joined.n:
                parts.append(joined)
        if not parts:
            return Relation()
        cols = parts[0].keys()
        return Relation({c: np.concatenate([p[c] for p in parts]) for c in cols})
