"""Vectorized relational algebra over column blocks.

A Relation is a dict of equal-length int64 numpy columns keyed by variable
name. Joins are sort-merge over composite keys, the vectorized analogue of
RDF-3X's merge joins over sorted permutation-index scans.

Every equi-join primitive here (`join`, `semijoin`, `filter_in_ranges`)
shares one machinery: `composite_keys` packs the `on` columns of both sides
into order-isomorphic int64 scalars (arithmetic range packing, with a dense
np.unique ranking fallback when the domain product would overflow), and the
two-phase rank/gather core turns the rank pass into a single call on the
`kernels/ops.merge_join_ranks` backend (numpy searchsorted oracle on CPU,
Pallas counting kernel on TPU, jitted CPU twin / interpret mode for tests)
followed by a static-shape CSR cumsum/repeat gather (`squadtree.csr_gather`).

Two bit-identical fast paths sit in front of the sort: relations carry
`sorted_by` (index scans report their permutation-index order, join outputs
are ordered by their `on` key), which turns the stable argsort into the
identity, and a per-relation `_keycache` replays a packing's per-column
(vmin, span) params against new partners so `_join_chain` steps that share
an `on` prefix never re-sort the big side.

The pre-rework per-pattern numpy implementations — lexsort + per-column
np.unique dense ranking + range expansion — are kept verbatim as the
`*_looped` oracles; the merge path must stay bit-identical to them
(including row order: both sort stably by the same composite key).
"""
from __future__ import annotations

import numpy as np

from .query import TriplePattern, Var
from .squadtree import csr_gather
from .store import G, O, P, QuadStore, S

# `impl` knob for the relational primitives: "merge" is the two-phase
# rank/gather core (backend-dispatched rank pass), "looped" the pre-rework
# numpy oracle. "auto" resolves to "merge".
JOIN_IMPLS = ("auto", "merge", "looped")


def resolve_join_impl(impl: str | None) -> str:
    impl = impl or "auto"
    if impl not in JOIN_IMPLS:
        raise ValueError(f"unknown join impl {impl!r}")
    return "merge" if impl == "auto" else impl


class Relation(dict):
    """dict[str, np.ndarray] with aligned rows.

    Two derived annotations ride along for the merge-join fast paths, both
    conservatively dropped whenever a column is (re)assigned:

    - ``sorted_by``: names the rows are known to be lexicographically sorted
      by (stable ties). When a join's ``on`` tuple is a prefix of it, the
      stable sorting permutation is the identity, so the argsort — the
      dominant cost at ≥32k rows — is skipped bit-identically.
    - ``_keycache``: per-``on`` packed composite keys (packing params +
      sorted keys + permutation), reused across `_join_chain` steps and
      driver blocks that re-join the same relation on the same columns.
    """

    sorted_by: tuple = ()

    def __setitem__(self, key, value):
        self.__dict__.pop("_keycache", None)
        self.__dict__.pop("sorted_by", None)  # back to the class default ()
        super().__setitem__(key, value)

    @property
    def n(self) -> int:
        return len(next(iter(self.values()), ()))

    def take(self, idx: np.ndarray) -> "Relation":
        return Relation({k: v[idx] for k, v in self.items()})

    def head(self, n: int) -> "Relation":
        return Relation({k: v[:n] for k, v in self.items()})

    @staticmethod
    def empty(cols: list[str]) -> "Relation":
        return Relation({c: np.empty(0, dtype=np.int64) for c in cols})


def scan_pattern(store: QuadStore, tp: TriplePattern) -> Relation:
    """Index scan for one quad pattern -> relation over its variables."""
    def const(t):
        return None if (t is None or isinstance(t, Var)) else int(t)
    rows, sort_cols = store.scan(g=const(tp.g), s=const(tp.s), p=const(tp.p),
                                 o=const(tp.o), return_order=True)
    slots = ((tp.g, G), (tp.s, S), (tp.p, P), (tp.o, O))
    var_cols: dict[str, list[int]] = {}
    for term, col in slots:
        if isinstance(term, Var):
            var_cols.setdefault(term.name, []).append(col)
    # repeated variable within one pattern -> intra-row equality filter
    mask = np.ones(len(rows), dtype=bool)
    for cols in var_cols.values():
        for c in cols[1:]:
            mask &= rows[:, cols[0]] == rows[:, c]
    if not mask.all():
        rows = rows[mask]
    rel = Relation({name: rows[:, cols[0]].copy()
                    for name, cols in var_cols.items()})
    # rows come back lexicographically sorted by `sort_cols` (the chosen
    # index's columns past the bound prefix); translate to variable names,
    # skipping bound columns (constant over the result) and repeat
    # occurrences of a variable (tied by the equality filter above) —
    # neither affects the lexicographic order of what remains
    order: list[str] = []
    for c in sort_cols:
        for name, cols in var_cols.items():
            if c in cols:
                if name not in order:
                    order.append(name)
                break
    rel.sorted_by = tuple(order)
    return rel


# ---------------------------------------------------------------------------
# shared composite-key machinery
# ---------------------------------------------------------------------------

# packed keys must stay strictly below int64-max, the rank kernel's padding
# sentinel (kernels/merge_join.py)
_KEY_SPACE = (1 << 63) - 1


def composite_keys(a: Relation, b: Relation,
                   on: list[str]) -> tuple[np.ndarray, np.ndarray, int]:
    """Order-isomorphic int64 scalar keys for the composite `on` columns,
    plus the exact key-domain bound `scale` (keys live in [0, scale))."""
    ka, kb, scale, _ = _composite_keys_meta(a, b, on)
    return ka, kb, scale


def _composite_keys_meta(a: Relation, b: Relation, on: list[str]):
    """Packed keys, scale, and the per-column (vmin, span) packing params.

    Columns are range-offset and mixed arithmetically (key = key * span +
    (v - vmin)), so the packed scalars compare exactly like the column
    tuples and no per-column sorting is needed. When the running domain
    product would leave [0, 2^63-1), the offending column — and, if still
    necessary, the accumulated prefix keys — are dense-ranked over the union
    of both sides (np.unique), which bounds every factor by the row count
    while preserving order. Both sides must be non-empty.

    The returned params are None once any dense-rank fallback fires (the
    ranking depends on both sides' value sets, so the packing can't be
    replayed against a different partner); otherwise they fully determine
    the packing, and any relation whose column values fall inside the
    per-column [vmin, vmin+span) windows packs to keys comparable with —
    and bit-identical against — this call's.
    """
    ka = np.zeros(a.n, dtype=np.int64)
    kb = np.zeros(b.n, dtype=np.int64)
    scale = 1  # python int: packed keys so far live in [0, scale)
    params: list[tuple[int, int]] | None = []
    for c in on:
        va = np.asarray(a[c], dtype=np.int64)
        vb = np.asarray(b[c], dtype=np.int64)
        vmin = int(min(va.min(), vb.min()))
        span = int(max(va.max(), vb.max())) - vmin + 1
        if scale * span > _KEY_SPACE:
            params = None
            uniq, inv = np.unique(np.concatenate([va, vb]),
                                  return_inverse=True)
            va, vb = inv[:len(va)], inv[len(va):]
            vmin, span = 0, len(uniq)
            if scale * span > _KEY_SPACE:
                uniq, inv = np.unique(np.concatenate([ka, kb]),
                                      return_inverse=True)
                ka, kb = inv[:len(ka)], inv[len(ka):]
                scale = len(uniq)
                if scale * span > _KEY_SPACE:
                    # both factors are now bounded by the combined row
                    # count, so this needs > ~3e9 rows per side — raise
                    # rather than let the packing wrap int64 silently
                    raise OverflowError(
                        f"composite key domain {scale}x{span} exceeds int64")
        if params is not None:
            params.append((vmin, span))
        ka = ka * np.int64(span) + (va - np.int64(vmin))
        kb = kb * np.int64(span) + (vb - np.int64(vmin))
        scale *= span
    return ka, kb, scale, (tuple(params) if params is not None else None)


def _pack_with_params(rel: Relation, on: list[str],
                      params: tuple) -> np.ndarray:
    """Replay a `_composite_keys_meta` packing against another relation.

    Only valid when `_params_fit` holds; then every key lands in the same
    [0, scale) domain with the same ordering, so ranks against keys packed
    by the original call are bit-identical to a joint repacking.
    """
    k = np.zeros(rel.n, dtype=np.int64)
    for c, (vmin, span) in zip(on, params):
        v = np.asarray(rel[c], dtype=np.int64)
        k = k * np.int64(span) + (v - np.int64(vmin))
    return k


def _params_fit(rel: Relation, on: list[str], params: tuple) -> bool:
    """Do `rel`'s `on` values fall inside the packing's per-column windows?"""
    for c, (vmin, span) in zip(on, params):
        v = np.asarray(rel[c], dtype=np.int64)
        if int(v.min()) < vmin or int(v.max()) >= vmin + span:
            return False
    return True


# Per-Relation `_keycache` budget. Each entry holds the packed keys plus the
# sorting permutation (two int64 arrays the length of the relation), so an
# unbounded cache on a long-lived scan-cache relation grows with every
# distinct `on` tuple it is ever joined by. Insertion order doubles as
# recency order (hits are re-inserted at the end), so eviction is LRU.
KEYCACHE_MAX_ENTRIES = 8
KEYCACHE_MAX_BYTES = 1 << 27        # 128 MiB of cached keys+perms per Relation


def _cache_nbytes(ent) -> int:
    return ent[2].nbytes + ent[3].nbytes


def _cached_pack(rel: Relation, on_t: tuple):
    cache = rel.__dict__.get("_keycache")
    if not cache:
        return None
    ent = cache.get(on_t)
    if ent is not None:             # touch: move to the recent end
        cache.pop(on_t)
        cache[on_t] = ent
    return ent


def _store_pack(rel: Relation, on_t: tuple, params, scale: int,
                ks: np.ndarray, perm: np.ndarray) -> None:
    if params is None:
        return
    cache = rel.__dict__.setdefault("_keycache", {})
    if on_t in cache:               # keep the first packing, but touch it
        cache[on_t] = cache.pop(on_t)
        return
    cache[on_t] = (params, scale, ks, perm)
    # evict least-recently-used entries beyond the budget; the fresh entry
    # (at the recent end) always survives, even when alone over-budget
    while len(cache) > 1 and (
            len(cache) > KEYCACHE_MAX_ENTRIES
            or sum(_cache_nbytes(e) for e in cache.values())
            > KEYCACHE_MAX_BYTES):
        cache.pop(next(iter(cache)))


def _sorted_keys(rel: Relation, k: np.ndarray, scale: int,
                 on_t: tuple) -> tuple[np.ndarray, np.ndarray]:
    """`_sort_with_perm`, skipping the sort when `rel`'s rows are already
    sorted by an `on_t` prefix (then the stable permutation is the
    identity and the packed keys are already in order)."""
    if rel.sorted_by[:len(on_t)] == on_t:
        return k, np.arange(rel.n, dtype=np.int64)
    return _sort_with_perm(k, scale)


def _sort_with_perm(k: np.ndarray, scale: int) -> tuple[np.ndarray,
                                                        np.ndarray]:
    """Sorted keys + the stable sorting permutation.

    When the (key, row-index) pair packs into int64 — scale tracked by
    `composite_keys` leaves ceil(log2(n)) free low bits — one vectorized
    np.sort of the packed array replaces np.argsort(kind="stable"), whose
    mergesort is ~10x slower than numpy's SIMD introsort on ints; the row
    index doubles as the tiebreaker, so stability is preserved. Falls back
    to the stable argsort when the pack would overflow.
    """
    n = len(k)
    bits = max((n - 1).bit_length(), 1)
    if scale <= (_KEY_SPACE >> bits):
        packed = np.sort((k << np.int64(bits))
                         | np.arange(n, dtype=np.int64))
        return packed >> np.int64(bits), packed & np.int64((1 << bits) - 1)
    perm = np.argsort(k, kind="stable")
    return k[perm], perm


def _ranks(table: np.ndarray, probes: np.ndarray,
           backend: str | None, side: str = "both"):
    """Insertion ranks of probes in the sorted table, via the dispatched
    rank backend; side="both" -> (left, right), else the one bound."""
    from ..kernels import ops  # lazy: keep core importable without jax
    return ops.merge_join_ranks(table, probes, backend=backend, side=side)


def _member_sorted(table: np.ndarray, probes: np.ndarray,
                   backend: str | None) -> np.ndarray:
    """Membership of probes in the sorted (not necessarily unique) table:
    one left-rank pass plus a gather-compare."""
    lo = _ranks(table, probes, backend, side="left")
    hit = table[np.minimum(lo, len(table) - 1)] == probes
    return hit  # lo == len(table) ⇒ probe > table[-1] ⇒ compare is False


def _cartesian(a: Relation, b: Relation) -> Relation:
    na, nb = a.n, b.n
    out = Relation()
    ia = np.repeat(np.arange(na), nb)
    ib = np.tile(np.arange(nb), na)
    for k, v in a.items():
        out[k] = v[ia]
    for k, v in b.items():
        out[k] = v[ib]
    return out


# ---------------------------------------------------------------------------
# join
# ---------------------------------------------------------------------------

def join(a: Relation, b: Relation, on: list[str] | None = None,
         impl: str | None = None, backend: str | None = None) -> Relation:
    """Natural equi-join on shared variables (two-phase sort-merge).

    Phase 1 (rank/count): stable-sort both packed key arrays, then one
    backend call yields each probe row's [lo, hi) match range and CSR width
    `hi - lo`. Phase 2 (gather): cumsum/repeat materializes the matching
    (a-row, b-row) index pairs with static shapes and gathers the output
    columns once. Output order is bit-identical to `join_looped`.
    """
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on:  # cartesian product
        return _cartesian(a, b)
    if a.n == 0 or b.n == 0:
        return Relation.empty(sorted(set(a) | set(b)))
    if resolve_join_impl(impl) == "looped":
        return join_looped(a, b, on)
    on_t = tuple(on)
    sides = None
    # reuse one side's cached packing when the other side's values fit its
    # per-column windows (same params ⇒ comparable keys ⇒ identical ranks);
    # prefer b's cache — in `_join_chain` b is the large per-pattern scan
    # re-joined every driver block, so its sort is the one worth skipping
    for cached, fresh, b_cached in ((b, a, True), (a, b, False)):
        ent = _cached_pack(cached, on_t)
        if ent is not None and _params_fit(fresh, on, ent[0]):
            params, scale, kcs, oc = ent
            kf = _pack_with_params(fresh, on, params)
            kfs, of = _sorted_keys(fresh, kf, scale, on_t)
            _store_pack(fresh, on_t, params, scale, kfs, of)
            sides = (kfs, of, kcs, oc) if b_cached else (kcs, oc, kfs, of)
            break
    if sides is None:
        ka, kb, scale, params = _composite_keys_meta(a, b, on)
        kas, oa = _sorted_keys(a, ka, scale, on_t)
        kbs, ob = _sorted_keys(b, kb, scale, on_t)
        _store_pack(a, on_t, params, scale, kas, oa)
        _store_pack(b, on_t, params, scale, kbs, ob)
        sides = (kas, oa, kbs, ob)
    kas, oa, kbs, ob = sides
    lo, hi = _ranks(kbs, kas, backend)
    cnt = hi - lo
    ia = np.repeat(np.arange(a.n), cnt)
    ib = csr_gather(lo, cnt)
    src_a, src_b = oa[ia], ob[ib]
    out = Relation({k: v[src_a] for k, v in a.items()})
    for k, v in b.items():
        if k not in out:
            out[k] = v[src_b]
    # output rows follow a's sorted key order (stable within ties), so the
    # next chain step joining on the same prefix skips its argsort entirely
    out.sorted_by = on_t
    return out


def join_looped(a: Relation, b: Relation,
                on: list[str] | None = None) -> Relation:
    """Pre-rework numpy join (lexsort + per-column dense ranking +
    searchsorted + range expansion), kept as the bit-identical oracle."""
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on:  # cartesian product
        return _cartesian(a, b)
    if a.n == 0 or b.n == 0:
        return Relation.empty(sorted(set(a) | set(b)))
    # sort both sides by the composite key
    oa = _composite_key(a, on)
    ob = _composite_key(b, on)
    a_sorted = a.take(oa)
    b_sorted = b.take(ob)
    # dense-rank the key domain on the union so searchsorted works per-column
    ka = _rank_rows(a_sorted, b_sorted, on)
    kb = _rank_rows(b_sorted, a_sorted, on)
    lo = np.searchsorted(kb, ka, "left")
    hi = np.searchsorted(kb, ka, "right")
    cnt = hi - lo
    ia = np.repeat(np.arange(a_sorted.n), cnt)
    ib = _expand_ranges(lo, hi)
    out = Relation()
    for k, v in a_sorted.items():
        out[k] = v[ia]
    for k, v in b_sorted.items():
        if k not in out:
            out[k] = v[ib]
    return out


def _composite_key(rel: Relation, names: list[str]) -> np.ndarray:
    """Lexicographic rank array for the given columns (stable)."""
    cols = [rel[n] for n in names]
    order = np.lexsort(tuple(reversed(cols)))
    return order


def _rank_rows(x: Relation, other: Relation, on: list[str]) -> np.ndarray:
    """Map composite keys to comparable scalars via shared dense ranking."""
    both = [np.concatenate([x[c], other[c]]) for c in on]
    nx = x.n
    key = np.zeros(len(both[0]), dtype=np.int64)
    for col in both:
        uniq, inv = np.unique(col, return_inverse=True)
        key = key * np.int64(len(uniq)) + inv  # may wrap for huge domains;
        # domain sizes here are bounded by block cardinalities (<2^20 each)
    return key[:nx]


def _expand_ranges(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Concatenate arange(lo[i], hi[i]) for all i, vectorized."""
    cnt = hi - lo
    nz = cnt > 0
    l, c = lo[nz], cnt[nz]
    total = int(c.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    out[0] = l[0]
    if len(l) > 1:
        pos = np.cumsum(c)[:-1]
        out[pos] = l[1:] - (l[:-1] + c[:-1] - 1)
    return np.cumsum(out)


# ---------------------------------------------------------------------------
# semijoin
# ---------------------------------------------------------------------------

def semijoin(a: Relation, b: Relation, on: list[str] | None = None,
             impl: str | None = None,
             backend: str | None = None) -> Relation:
    """Rows of `a` that have at least one match in `b` (original order).

    Same machinery as `join`, but only the b side is sorted and a single
    left-rank pass drives the membership test — no gather phase.
    """
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on or a.n == 0:
        return a
    if b.n == 0:
        return a.take(np.empty(0, dtype=np.int64))
    if resolve_join_impl(impl) == "looped":
        return semijoin_looped(a, b, on)
    on_t = tuple(on)
    ent = _cached_pack(b, on_t)
    if ent is not None and _params_fit(a, on, ent[0]):
        kbs = ent[2]  # already sorted (stable sort == np.sort on values)
        ka = _pack_with_params(a, on, ent[0])
    else:
        ka, kb, _ = composite_keys(a, b, on)
        kbs = kb if b.sorted_by[:len(on_t)] == on_t else np.sort(kb)
    out = a.take(np.flatnonzero(_member_sorted(kbs, ka, backend)))
    out.sorted_by = a.sorted_by  # flatnonzero keeps row order
    return out


def semijoin_looped(a: Relation, b: Relation,
                    on: list[str] | None = None) -> Relation:
    """Pre-rework numpy semijoin, kept as the bit-identical oracle."""
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on or a.n == 0:
        return a
    if b.n == 0:
        return a.take(np.empty(0, dtype=np.int64))
    ob = _composite_key(b, on)
    b_sorted = b.take(ob)
    ka = _rank_rows(a, b_sorted, on)
    kb = _rank_rows(b_sorted, a, on)
    kb_sorted = np.sort(kb)
    pos = np.searchsorted(kb_sorted, ka)
    pos = np.clip(pos, 0, len(kb_sorted) - 1)
    hit = kb_sorted[pos] == ka
    return a.take(np.flatnonzero(hit))


# ---------------------------------------------------------------------------
# SIP range/membership filter
# ---------------------------------------------------------------------------

def filter_in_ranges(rel: Relation, col: str, intervals: np.ndarray,
                     explicit: np.ndarray, impl: str | None = None,
                     backend: str | None = None) -> Relation:
    """SIP filter (paper §3.2.2): keep rows whose `col` id lies in any I-Range
    interval or equals an E-list id. Intervals are closed [lo, hi] rows.

    The E-list membership test is the semijoin's `_member_sorted` rank test
    against the sorted id table; the interval test uses the rank pass' upper
    bound
    against the interval starts with a running max of ends, so OVERLAPPING
    intervals are handled (v is in the union iff the max end among intervals
    starting <= v covers it). V* intervals are disjoint by construction, but
    the general case must hold too.
    """
    if rel.n == 0 or (len(intervals) == 0 and len(explicit) == 0):
        return rel if (len(intervals) or len(explicit)) else rel.take(
            np.empty(0, dtype=np.int64))
    if resolve_join_impl(impl) == "looped":
        return filter_in_ranges_looped(rel, col, intervals, explicit)
    vals = rel[col]
    keep = np.zeros(rel.n, dtype=bool)
    if len(intervals):
        iv = intervals[np.argsort(intervals[:, 0])]
        starts = iv[:, 0]
        ends = np.maximum.accumulate(iv[:, 1])
        pos = _ranks(starts, vals, backend, side="right") - 1
        ok = pos >= 0
        keep[ok] = vals[ok] <= ends[np.clip(pos[ok], 0, len(ends) - 1)]
    if len(explicit):
        keep |= _member_sorted(np.asarray(explicit, dtype=np.int64), vals,
                               backend)
    out = rel.take(np.flatnonzero(keep))
    out.sorted_by = rel.sorted_by  # flatnonzero keeps row order
    return out


def filter_in_ranges_looped(rel: Relation, col: str, intervals: np.ndarray,
                            explicit: np.ndarray) -> Relation:
    """Pre-rework numpy SIP filter, kept as the bit-identical oracle."""
    if rel.n == 0 or (len(intervals) == 0 and len(explicit) == 0):
        return rel if (len(intervals) or len(explicit)) else rel.take(
            np.empty(0, dtype=np.int64))
    vals = rel[col]
    keep = np.zeros(rel.n, dtype=bool)
    if len(intervals):
        iv = intervals[np.argsort(intervals[:, 0])]
        starts = iv[:, 0]
        ends = np.maximum.accumulate(iv[:, 1])
        pos = np.searchsorted(starts, vals, "right") - 1
        ok = pos >= 0
        keep[ok] = vals[ok] <= ends[np.clip(pos[ok], 0, len(ends) - 1)]
    if len(explicit):
        pos = np.searchsorted(explicit, vals)
        pos = np.clip(pos, 0, len(explicit) - 1)
        keep |= explicit[pos] == vals
    return rel.take(np.flatnonzero(keep))
