"""Synthetic YAGO3-like and LGD-like RDF datasets + benchmark queries (§4).

Mirrors the paper's evaluation setup at configurable scale:
- LGD-like: OpenStreetMap-flavored classes (hotel / park / police / road /
  pub) with POINT, LINESTRING and POLYGON geometries, reified type facts
  carrying exponential-distributed confidence scores, *complex*-shaped
  benchmark queries with (SS, RS) joins.
- YAGO3-like: POINT-only places with numeric attributes (population density,
  economic growth, ...), *star*- and *complex*-shaped queries.

Spatial layout is a mixture of Gaussian clusters (real geo data is skewed);
classes can be geographically localized so that some queries are highly
selective at the spatial join (the regime where SIP shines, paper Fig. 7)
while others overlap heavily (low selectivity, Q1-Q5 of LGD).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.dictionary import Dictionary
from ..core.query import Query, Ranking, SpatialFilter, TriplePattern, Var
from ..core.store import QuadStore, build_store


@dataclasses.dataclass
class SynthDataset:
    name: str
    store: QuadStore
    ns: dict                  # predicate/class name -> id
    queries: list             # benchmark Query objects
    raw_nbytes: int           # size of the raw quad table (Table 3 analogue)


class _Builder:
    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)
        self.dict = Dictionary.empty()
        self.quads: list[tuple[int, int, int, int]] = []
        self.geoms: dict = {}
        self.exact: dict = {}
        self._fact = 0

    def term(self, t: str) -> int:
        return self.dict.intern(t)

    def num(self, v: float) -> int:
        return self.dict.intern_numeric(v)

    def fact(self, s: int, p: int, o: int) -> int:
        g = self.term(f"_:fact{self._fact}")
        self._fact += 1
        self.quads.append((g, s, p, o))
        return g

    def plain(self, s: int, p: int, o: int) -> None:
        self.quads.append((0, s, p, o))

    def cluster_points(self, n: int, n_clusters: int, extent: float = 100.0,
                       region: tuple | None = None) -> np.ndarray:
        lo, hi = region if region else (0.0, extent)
        centers = self.rng.uniform(lo, hi, size=(n_clusters, 2))
        which = self.rng.integers(0, n_clusters, size=n)
        pts = centers[which] + self.rng.normal(0, extent * 0.02, size=(n, 2))
        return np.clip(pts, 0.0, extent)


def _geom_points(b: _Builder, pts: np.ndarray, kind: str,
                 extent: float) -> tuple[np.ndarray, list]:
    """exact geometry point sets per entity; returns (boxes, exact_list)."""
    n = len(pts)
    boxes = np.empty((n, 4))
    exact = []
    for i in range(n):
        if kind == "point":
            g = pts[i:i + 1]
        elif kind == "linestring":
            m = int(b.rng.integers(2, 6))
            g = pts[i] + np.cumsum(
                b.rng.normal(0, extent * 0.004, size=(m, 2)), axis=0)
        else:  # polygon ring
            m = int(b.rng.integers(4, 9))
            ang = np.sort(b.rng.uniform(0, 2 * np.pi, size=m))
            r = b.rng.uniform(extent * 0.001, extent * 0.008)
            g = pts[i] + r * np.stack([np.cos(ang), np.sin(ang)], axis=1)
        g = np.clip(g, 0.0, extent)
        exact.append(g)
        boxes[i] = [g[:, 0].min(), g[:, 1].min(), g[:, 0].max(), g[:, 1].max()]
    return boxes, exact


def _confidence(b: _Builder, n: int) -> np.ndarray:
    """Exponential-distributed confidence in [0, 1] (paper §4.1)."""
    return np.clip(b.rng.exponential(0.3, size=n), 0.0, 1.0)


# ---------------------------------------------------------------------------
# LGD-like
# ---------------------------------------------------------------------------

def make_lgd(n_per_class: int = 400, seed: int = 0,
             l_max: int = 8, leaf_capacity: int = 64,
             block: int = 256) -> SynthDataset:
    b = _Builder(seed)
    extent = 100.0
    ns = {k: b.term(k) for k in (
        "rdf:type", "hasGeometry", "hasConfidence", "name", "label",
        "stars", "area", "lanes", "class:hotel", "class:park",
        "class:police", "class:road", "class:pub")}

    # class -> (geometry kind, spatial region, extra predicates)
    # park/police/pub are geographically localized with a narrow overlap so
    # their pair queries are highly spatially selective (paper Fig. 7
    # regime) while still having >= k results at benchmark scale.
    classes = {
        "class:hotel": ("point", (0.0, 100.0), ("name", "label", "stars")),
        "class:park": ("polygon", (0.0, 62.0), ("label", "area")),
        "class:police": ("point", (48.0, 100.0), ("name",)),
        "class:road": ("linestring", (0.0, 100.0), ("name", "lanes")),
        "class:pub": ("point", (0.0, 52.0), ("name", "label")),
    }
    for cname, (kind, region, preds) in classes.items():
        pts = b.cluster_points(n_per_class, 12, extent, region)
        boxes, exact = _geom_points(b, pts, kind, extent)
        conf = _confidence(b, n_per_class)
        for i in range(n_per_class):
            e = b.term(f"{cname}/e{i}")
            geo = b.term(f"geom:{cname}/e{i}")
            b.geoms[e] = boxes[i]
            b.exact[e] = exact[i]
            # reified type fact with confidence (RS-join structure)
            r = b.fact(e, ns["rdf:type"], ns[cname])
            b.plain(r, ns["hasConfidence"], b.num(float(conf[i])))
            b.plain(e, ns["hasGeometry"], geo)
            for pname in preds:
                b.plain(e, ns[pname], b.term(f"{pname}:{cname}/{i % 97}"))

    store = build_store(np.array(b.quads, dtype=np.int64), b.dict,
                        geometry_predicate=ns["hasGeometry"],
                        geometries=b.geoms, exact_geoms=b.exact,
                        l_max=l_max, leaf_capacity=leaf_capacity, block=block)
    ns = {k: store.dictionary.term_to_id[k] for k in ns}

    def pair_query(cls_a: str, cls_b: str, dist: float, k: int = 100,
                   extra_a: tuple = (), extra_b: tuple = ()) -> Query:
        """?place typed cls_a (reified, conf-ranked) near ?nplace typed cls_b."""
        pa, pb = Var("place"), Var("nplace")
        patterns = [
            TriplePattern(pa, Var("typePred1"), ns[cls_a], g=Var("r")),
            TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
            TriplePattern(pa, ns["hasGeometry"], Var("g1")),
            TriplePattern(pb, Var("typePred2"), ns[cls_b], g=Var("r1")),
            TriplePattern(Var("r1"), ns["hasConfidence"], Var("conf1")),
            TriplePattern(pb, ns["hasGeometry"], Var("g2")),
        ]
        for p in extra_a:
            patterns.append(TriplePattern(pa, ns[p], Var(f"a_{p}")))
        for p in extra_b:
            patterns.append(TriplePattern(pb, ns[p], Var(f"b_{p}")))
        return Query(
            select=(pa, pb),
            patterns=tuple(patterns),
            spatial=SpatialFilter(Var("g1"), Var("g2"), dist),
            ranking=Ranking(((Var("conf"), 1.0), (Var("conf1"), 1.0)),
                            descending=False),  # ORDER BY ASC(conf+conf1)
            k=k)

    d_lo, d_hi = extent * 0.02, extent * 0.06
    queries = [
        pair_query("class:hotel", "class:park", d_hi),                    # Q1
        pair_query("class:park", "class:police", d_lo),                   # Q2
        pair_query("class:hotel", "class:police", d_lo, extra_a=("label",)),  # Q3
        pair_query("class:pub", "class:police", d_lo,
                   extra_a=("name", "label"), extra_b=("name",)),         # Q4
        pair_query("class:park", "class:police", d_lo,
                   extra_a=("label",), extra_b=("name",)),                # Q5
        pair_query("class:hotel", "class:road", d_hi),                    # Q6
        pair_query("class:road", "class:hotel", d_hi),                    # Q7 (swap)
        pair_query("class:park", "class:road", d_hi, extra_a=("label",)),  # Q8
    ]
    raw = np.array(b.quads, dtype=np.int64).nbytes
    return SynthDataset("lgd", store, ns, queries, raw)


# ---------------------------------------------------------------------------
# Bulk scaling generator (1M -> 100M quads)
# ---------------------------------------------------------------------------

def make_scale(n_quads: int, seed: int = 0,
               l_max: int = 8, leaf_capacity: int = 256,
               block: int = 4096, n_conf_bins: int = 4096) -> SynthDataset:
    """LGD-shaped dataset built with bulk numpy ops, viable at 10M-100M quads.

    The per-entity Python loops of `make_lgd`/`make_yago` cap out around
    ~1M quads; this generator constructs the quad table, geometry boxes and
    numeric literals as whole arrays (only the handful of predicate/class
    terms and the `n_conf_bins` quantized confidence literals go through
    the dictionary), keeping the paper's evaluated shape: two localized
    spatial classes, reified type facts ranked by exponential confidence,
    attribute quads for CS variety, and the LGD pair query (SS + RS joins,
    spatial filter, ORDER BY ASC(conf+conf1) LIMIT k).

    Entities carry box MBRs (not points), so quadrant-line straddlers give
    the interior nodes populated E-lists — the regime the compressed
    `PackedEList` tier targets.

    ~4.5 quads per entity: geometry + reified type + confidence per
    entity, attr1 for all, attr2 for every other entity.
    """
    rng = np.random.default_rng(seed)
    d = Dictionary.empty()
    ns = {k: d.intern(k) for k in (
        "rdf:type", "hasGeometry", "hasConfidence", "attr1", "attr2",
        "class:poi", "class:site")}
    # quantized confidence literals (bounded distinct count: the dictionary
    # round-trip stays O(n_conf_bins), not O(n_quads))
    grid = np.round(np.linspace(0.0, 1.0, n_conf_bins), 6)
    conf_ids = np.array([d.intern_numeric(float(v)) for v in grid],
                        dtype=np.int64)

    n_ent = max(int(n_quads / 4.5), 2)
    extent = 100.0
    # plain-id ranges (disjoint, far below the S bit)
    e0 = 1 << 20                       # entities
    f0 = e0 + n_ent                    # reified type-fact ids
    g0 = f0 + n_ent                    # geometry objects
    a0 = g0 + n_ent                    # attribute object pool
    n_pool = 1 << 16
    d._next = a0 + n_pool

    ent = e0 + np.arange(n_ent, dtype=np.int64)
    fact = f0 + np.arange(n_ent, dtype=np.int64)
    geo = g0 + np.arange(n_ent, dtype=np.int64)

    # two localized classes: poi in [0, 62], site in [48, 100] — the narrow
    # overlap keeps the pair query spatially selective (Fig. 7 regime)
    is_site = np.arange(n_ent) % 2 == 1
    cls = np.where(is_site, ns["class:site"], ns["class:poi"])
    n_cl = 64
    lo = np.where(is_site, 48.0, 0.0)
    hi = np.where(is_site, 100.0, 62.0)
    centers = rng.uniform(0.0, 1.0, size=(n_cl, 2))
    which = rng.integers(0, n_cl, size=n_ent)
    pts = centers[which] * (hi - lo)[:, None] + lo[:, None] \
        + rng.normal(0, extent * 0.02, size=(n_ent, 2))
    pts = np.clip(pts, 0.0, extent)
    half = rng.lognormal(np.log(extent * 0.002), 0.6, size=(n_ent, 2))
    boxes = np.concatenate([np.clip(pts - half, 0, extent),
                            np.clip(pts + half, 0, extent)], axis=1)

    conf_bin = np.minimum((rng.exponential(0.3, size=n_ent) *
                           (n_conf_bins - 1)).astype(np.int64),
                          n_conf_bins - 1)
    conf_obj = conf_ids[conf_bin]
    attr1_obj = a0 + rng.integers(0, n_pool, size=n_ent)
    has_a2 = np.arange(n_ent) % 2 == 0
    attr2_obj = a0 + rng.integers(0, n_pool, size=int(has_a2.sum()))

    zeros = np.zeros(n_ent, dtype=np.int64)
    quads = np.concatenate([
        np.stack([zeros, ent, np.full(n_ent, ns["hasGeometry"]), geo], 1),
        np.stack([fact, ent, np.full(n_ent, ns["rdf:type"]), cls], 1),
        np.stack([zeros, fact, np.full(n_ent, ns["hasConfidence"]),
                  conf_obj], 1),
        np.stack([zeros, ent, np.full(n_ent, ns["attr1"]), attr1_obj], 1),
        np.stack([zeros[has_a2], ent[has_a2],
                  np.full(int(has_a2.sum()), ns["attr2"]),
                  attr2_obj], 1),
    ]).astype(np.int64)

    geometries = dict(zip(ent.tolist(), boxes))
    store = build_store(quads, d, geometry_predicate=ns["hasGeometry"],
                        geometries=geometries, exact_geoms=None,
                        l_max=l_max, leaf_capacity=leaf_capacity,
                        block=block)
    ns = {k: store.dictionary.term_to_id[k] for k in ns}

    def pair_query(cls_a: str, cls_b: str, dist: float, k: int = 100) -> Query:
        pa, pb = Var("place"), Var("nplace")
        patterns = (
            TriplePattern(pa, Var("typePred1"), ns[cls_a], g=Var("r")),
            TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
            TriplePattern(pa, ns["hasGeometry"], Var("g1")),
            TriplePattern(pb, Var("typePred2"), ns[cls_b], g=Var("r1")),
            TriplePattern(Var("r1"), ns["hasConfidence"], Var("conf1")),
            TriplePattern(pb, ns["hasGeometry"], Var("g2")),
        )
        return Query(
            select=(pa, pb), patterns=patterns,
            spatial=SpatialFilter(Var("g1"), Var("g2"), dist),
            ranking=Ranking(((Var("conf"), 1.0), (Var("conf1"), 1.0)),
                            descending=False), k=k)

    queries = [
        pair_query("class:poi", "class:site", extent * 0.005),
        pair_query("class:site", "class:poi", extent * 0.002),
    ]
    return SynthDataset("scale", store, ns, queries, quads.nbytes)


# ---------------------------------------------------------------------------
# YAGO3-like
# ---------------------------------------------------------------------------

def make_yago(n_places: int = 1500, seed: int = 1,
              l_max: int = 8, leaf_capacity: int = 64,
              block: int = 256) -> SynthDataset:
    b = _Builder(seed)
    extent = 360.0
    ns = {k: b.term(k) for k in (
        "hasPopulationDensity", "hasNumberOfPeople", "hasEconomicGrowth",
        "hasInflation", "isLocatedIn", "hasNeighbor", "isConnectedTo",
        "hasGeometry", "hasConfidence", "happenedIn", "wasBornIn", "diedIn",
        "rdf:type", "class:city", "class:village", "class:event",
        "class:person")}

    n_loc = max(8, n_places // 50)
    locations = [b.term(f"loc{i}") for i in range(n_loc)]

    pts = b.cluster_points(n_places, 25, extent)
    boxes, exact = _geom_points(b, pts, "point", extent)
    popul = b.rng.lognormal(5.0, 1.5, size=n_places)
    people = b.rng.lognormal(8.0, 2.0, size=n_places)
    growth = b.rng.normal(2.0, 3.0, size=n_places)
    infl = b.rng.normal(4.0, 2.0, size=n_places)
    places = []
    for i in range(n_places):
        e = b.term(f"place{i}")
        places.append(e)
        b.geoms[e] = boxes[i]
        b.exact[e] = exact[i]
        b.plain(e, ns["hasGeometry"], b.term(f"geom:place{i}"))
        b.plain(e, ns["isLocatedIn"], locations[i % n_loc])
        kind = i % 3
        if kind == 0:  # "city": density + growth (+ inflation sometimes)
            b.plain(e, ns["hasPopulationDensity"], b.num(float(popul[i])))
            b.plain(e, ns["hasEconomicGrowth"], b.num(float(growth[i])))
            if i % 5 == 0:
                b.plain(e, ns["hasInflation"], b.num(float(infl[i])))
        elif kind == 1:  # "town": population count
            b.plain(e, ns["hasNumberOfPeople"], b.num(float(people[i])))
        else:  # both flavors
            b.plain(e, ns["hasPopulationDensity"], b.num(float(popul[i])))
            b.plain(e, ns["hasNumberOfPeople"], b.num(float(people[i])))
        if i % 4 == 0:
            b.plain(e, ns["hasNeighbor"], places[max(0, i - 1)])
        if i % 6 == 0:
            b.plain(b.term(f"conn{i}"), ns["isConnectedTo"], e)

    # reified event/person facts for the complex queries
    n_ev = n_places // 3
    conf = _confidence(b, n_ev)
    for i in range(n_ev):
        ev = b.term(f"event{i}")
        target = places[int(b.rng.integers(0, n_places))]
        r = b.fact(ev, ns["happenedIn"], target)
        b.plain(r, ns["hasConfidence"], b.num(float(conf[i])))
        person = b.term(f"person{i}")
        r2 = b.fact(person, ns["wasBornIn"],
                    places[int(b.rng.integers(0, n_places))])
        b.plain(r2, ns["hasConfidence"], b.num(float(1.0 - conf[i])))

    store = build_store(np.array(b.quads, dtype=np.int64), b.dict,
                        geometry_predicate=ns["hasGeometry"],
                        geometries=b.geoms, exact_geoms=b.exact,
                        l_max=l_max, leaf_capacity=leaf_capacity, block=block)
    ns = {k: store.dictionary.term_to_id[k] for k in ns}

    pa, pb = Var("place"), Var("nplace")
    d = extent * 0.02

    def star(extra_a: tuple, extra_b: tuple, k: int = 100) -> Query:
        """Star-shaped: both sides are attribute cliques on the subject."""
        patterns = [
            TriplePattern(pa, ns["hasPopulationDensity"], Var("popul")),
            TriplePattern(pa, ns["hasGeometry"], Var("g1")),
            TriplePattern(pa, ns["isLocatedIn"], Var("loc1")),
            TriplePattern(pb, ns["hasNumberOfPeople"], Var("popul1")),
            TriplePattern(pb, ns["hasGeometry"], Var("g2")),
            TriplePattern(pb, ns["isLocatedIn"], Var("loc2")),
        ]
        patterns += [TriplePattern(pa, ns[p], Var(f"a_{p}")) for p in extra_a]
        patterns += [TriplePattern(pb, ns[p], Var(f"b_{p}")) for p in extra_b]
        return Query(select=(pa, pb), patterns=tuple(patterns),
                     spatial=SpatialFilter(Var("g1"), Var("g2"), d),
                     ranking=Ranking(((Var("popul"), 1.0), (Var("popul1"), 1.0)),
                                     descending=False), k=k)

    def complex_reified(pred: str, k: int = 100) -> Query:
        """Reified event near an attribute place (OS/RS joins)."""
        patterns = (
            TriplePattern(Var("a"), ns[pred], Var("b"), g=Var("r")),
            TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
            TriplePattern(Var("b"), ns["hasGeometry"], Var("g1")),
            TriplePattern(pb, ns["hasNumberOfPeople"], Var("popul1")),
            TriplePattern(pb, ns["hasGeometry"], Var("g2")),
            TriplePattern(pb, ns["isLocatedIn"], Var("loc2")),
        )
        return Query(select=(Var("a"), pb), patterns=patterns,
                     spatial=SpatialFilter(Var("g1"), Var("g2"), d),
                     ranking=Ranking(((Var("conf"), 1.0), (Var("popul1"), 1.0)),
                                     descending=False), k=k)

    queries = [
        star((), ()),                                            # Q1
        star(("hasEconomicGrowth",), ()),                        # Q2
        star(("hasEconomicGrowth",), ("isLocatedIn",)),          # Q3
        star(("hasEconomicGrowth", "hasNeighbor"), ()),          # Q4
        complex_reified("happenedIn"),                           # Q5
        complex_reified("wasBornIn"),                            # Q6
        star(("hasNeighbor",), ()),                              # Q7
        complex_reified("happenedIn", k=10),                     # Q8
    ]
    raw = np.array(b.quads, dtype=np.int64).nbytes
    return SynthDataset("yago3", store, ns, queries, raw)
