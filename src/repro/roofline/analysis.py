"""Three-term roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute term    = HLO_dot_FLOPs_per_device / peak_FLOPs      [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_bytes_per_device / link_bw      [s]

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. All artifact numbers are per-device and loop-weighted
(hlo_parse), so the terms are directly comparable step times.

MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) convention with
N_active for MoE; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy
waste.

    PYTHONPATH=src python -m repro.roofline.analysis [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    """Useful-math FLOPs per device per step (6ND / 2ND convention)."""
    from ..configs import registry
    mod = registry.get(arch)
    spec = mod.SHAPES[shape]
    cfg = mod.CONFIG
    fam = mod.FAMILY

    if fam in ("lm", "moe"):
        n = cfg.n_active_params() if fam == "moe" else cfg.n_params()
        if spec["kind"] == "train":
            tok = spec["global_batch"] * spec["seq_len"]
            return 6.0 * n * tok / n_devices
        if spec["kind"] == "prefill":
            tok = spec["global_batch"] * spec["seq_len"]
            return 2.0 * n * tok / n_devices
        tok = spec["global_batch"]  # decode: one token per sequence
        return 2.0 * n * tok / n_devices
    return 0.0


def _gnn_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from ..configs import registry
    mod = registry.get(arch)
    spec = mod.SHAPES[shape]
    cfg = mod.CONFIG
    fam = mod.FAMILY
    if spec["kind"] == "sampled":
        b = spec["batch_nodes"]
        f1, f2 = spec["fanout"]
        nodes = b * (1 + f1 + f1 * f2)
        edges = b * f1 + b * f1 * f2
    elif spec["kind"] == "batched":
        nodes = spec["n_nodes"] * spec["batch"]
        edges = spec["n_edges"] * spec["batch"]
    else:
        nodes, edges = spec["n_nodes"], spec["n_edges"]
    if fam == "graphcast":
        h = cfg.d_hidden
        fl = 2 * nodes * cfg.n_vars * h          # encode/decode embeds
        fl += cfg.n_layers * (2 * edges * 3 * h * h + 2 * nodes * 2 * h * h)
        fl += 2 * (4 * nodes) * 2 * h * h * 2    # bipartite MLPs
        return 3.0 * fl / n_devices              # fwd+bwd
    if fam == "nequip":
        from ..models.equivariant import allowed_paths
        paths = len(allowed_paths(cfg.l_max))
        c = cfg.n_channels
        per_edge = paths * c * (9 + 25) + cfg.n_rbf * cfg.radial_hidden \
            + cfg.radial_hidden * paths * c
        fl = cfg.n_layers * (2 * edges * per_edge
                             + 2 * nodes * (cfg.l_max + 1) * c * c * 5)
        return 3.0 * fl / n_devices
    # gcn / sage
    dims = [spec["d_feat"]] + [cfg.d_hidden] * (cfg.n_layers - 1) \
        + [max(spec["n_classes"], 2)]
    fl = 0.0
    for a, b2 in zip(dims[:-1], dims[1:]):
        fl += 2 * nodes * a * b2 * (2 if cfg.arch == "sage" else 1)
        fl += edges * a  # message gather+reduce
    return 3.0 * fl / n_devices


def _recsys_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from ..configs import registry
    mod = registry.get(arch)
    spec = mod.SHAPES[shape]
    cfg = mod.CONFIG
    d = cfg.embed_dim
    per_tok = cfg.n_blocks * (4 * d * d + 2 * d * cfg.d_ff) * 2
    if spec["kind"] == "train":
        fl = 3 * spec["batch"] * cfg.seq_len * (per_tok
                                                + cfg.seq_len * d * 2) + \
            3 * spec["batch"] * cfg.seq_len * 2 * d * 2
        return fl / n_devices
    b = spec.get("batch", 1)
    enc = b * cfg.seq_len * (per_tok + cfg.seq_len * d * 2)
    if spec["kind"] == "retrieval":
        score = b * spec["n_candidates"] * 2 * d
    else:
        score = b * cfg.n_items * 2 * d
    return (enc + score) / n_devices


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    from ..configs import registry
    fam = registry.get(arch).FAMILY
    if fam in ("lm", "moe"):
        return model_flops_per_device(arch, shape, n_devices)
    if fam == "recsys":
        return _recsys_model_flops(arch, shape, n_devices)
    return _gnn_model_flops(arch, shape, n_devices)


def analyze(rec: dict) -> dict:
    coll = sum(v["bytes"] for v in rec["collectives"].values())
    t_compute = rec["dot_flops_per_device"] / PEAK_FLOPS
    t_memory = rec["hbm_bytes_per_device"] / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"], rec["n_devices"])
    ratio = mf / max(rec["dot_flops_per_device"], 1.0)
    # roofline fraction: useful-FLOPs time / bound time (an achievable-MFU
    # style score; 1.0 = useful math fully hides behind the binding term)
    frac = (mf / PEAK_FLOPS) / max(bound, 1e-12)
    peak_gib = (rec["arg_bytes"] + rec["temp_bytes"] + rec["out_bytes"]) / 2**30
    recs = {
        "compute": "compute-bound: raise MFU via larger tiles / fused "
                   "attention; remat ratio shows recompute overhead",
        "memory": "memory-bound: cut activation traffic (fusion, bf16 "
                  "carries, flash attention keeps logits in VMEM)",
        "collective": "collective-bound: reshard to cut all-gathers "
                      "(2D sharding, overlap, gradient compression)",
    }
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_device": mf, "useful_ratio": ratio,
        "roofline_fraction": frac, "peak_gib": peak_gib,
        "note": recs[dominant],
    }


def load_all(mesh: str | None = None, variant: str = "base") -> list:
    out = []
    for p in sorted(ART.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        if rec.get("variant", "base") != variant:
            continue
        out.append(analyze(rec))
    return out


def table(rows: list) -> str:
    hdr = (f"| {'arch':<18s} | {'shape':<13s} | {'mesh':<7s} | "
           f"{'compute s':>9s} | {'memory s':>9s} | {'collect s':>9s} | "
           f"{'bound':<10s} | {'6ND/HLO':>7s} | {'roofline%':>9s} | "
           f"{'peak GiB':>8s} |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in
                         ["arch" + " " * 14, "shape" + " " * 8, "mesh" + " " * 3,
                          "compute s", "memory  s", "collect s",
                          "bound" + " " * 5, "6ND/HLO", "roofline%", "peak GiB"]) + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:<18s} | {r['shape']:<13s} | {r['mesh']:<7s} | "
            f"{r['t_compute_s']:9.4f} | {r['t_memory_s']:9.4f} | "
            f"{r['t_collective_s']:9.4f} | {r['dominant']:<10s} | "
            f"{r['useful_ratio']:7.2f} | {r['roofline_fraction']*100:8.1f}% | "
            f"{r['peak_gib']:8.2f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    args = ap.parse_args()
    rows = load_all(args.mesh)
    print(table(rows))


if __name__ == "__main__":
    main()
