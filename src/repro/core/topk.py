"""Block-wise top-k accumulation with threshold early termination (§3.3).

The TopK state is the piece both N-Plan and S-Plan share: because the heap and
threshold θ survive across blocks and plans, switching plans at a
materialization point costs nothing (the paper's "zero plan-switch cost").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .join import Relation

NEG_INF = -np.inf


@dataclasses.dataclass
class TopK:
    k: int
    descending: bool = True
    scores: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, dtype=np.float64))
    rows: Relation = dataclasses.field(default_factory=Relation)

    def _key(self, s: np.ndarray) -> np.ndarray:
        return s if self.descending else -s

    @property
    def theta(self) -> float:
        """Score of the k-th result so far; -inf until the heap is full.

        (In ascending mode this is reported in *key space*: compare with
        `key(score) > theta` to test if a candidate can still enter.)
        """
        if len(self.scores) < self.k:
            return NEG_INF
        return float(self._key(self.scores).min())

    @property
    def full(self) -> bool:
        return len(self.scores) >= self.k

    def push(self, scores: np.ndarray, rows: Relation) -> None:
        if len(scores) == 0:
            return
        if self.rows.n == 0 and rows.n > 0:
            self.rows = Relation({c: np.empty(0, dtype=v.dtype)
                                  for c, v in rows.items()})
        all_scores = np.concatenate([self.scores, scores])
        all_rows = Relation({c: np.concatenate([self.rows[c], rows[c]])
                             for c in rows})
        order = np.argsort(-self._key(all_scores), kind="stable")[: self.k]
        self.scores = all_scores[order]
        self.rows = all_rows.take(order)

    def results(self) -> tuple[np.ndarray, Relation]:
        order = np.argsort(-self._key(self.scores), kind="stable")
        return self.scores[order], self.rows.take(order)

    def can_improve(self, upper_bound: float) -> bool:
        """Could a candidate with this score bound still enter the top-k?"""
        return (not self.full) or (self._keyf(upper_bound) > self.theta)

    def _keyf(self, s: float) -> float:
        return s if self.descending else -s


# ----------------------------------------------------- per-row partial merge --
def _merge2(a: tuple[np.ndarray, np.ndarray],
            b: tuple[np.ndarray, np.ndarray], k: int
            ) -> tuple[np.ndarray, np.ndarray]:
    s = np.concatenate([a[0], b[0]], axis=1)
    i = np.concatenate([a[1], b[1]], axis=1)
    if s.shape[1] <= k:
        return s, i
    order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(s, order, 1), np.take_along_axis(i, order, 1)


def merge_row_partials(parts: list, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Two-level absorption of per-row top-k partials (fused join backend).

    Level 1 happens inside the fused kernel (tiles of one column batch fold
    into an (M, k) partial); this is level 2: partials from successive column
    batches merge pairwise — tournament style, (M, 2k) peak — into the global
    per-row top-k. The dense (M, N) matrix is never rebuilt.

    `parts` is a list of (scores (M, w_i), idx (M, w_i)) pairs; returns the
    merged (scores (M, <=k), idx) sorted descending per row, -inf/-1 padded.
    """
    if not parts:
        raise ValueError("merge_row_partials needs at least one partial")
    parts = list(parts)
    while len(parts) > 1:
        nxt = [_merge2(parts[i], parts[i + 1], k)
               for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    s, i = parts[0]
    order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(s, order, 1), np.take_along_axis(i, order, 1)
