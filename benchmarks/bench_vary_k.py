"""Fig. 12: geometric-mean runtime vs k (APS / N / S / full-scan).

Expected: N-Plan wins small k, S-Plan wins large k, APS tracks the best,
full-scan flat in k.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import ExecConfig, StreakEngine
from repro.core.baselines import FullScanEngine

from . import common


def run() -> list:
    rows = []
    ds = common.dataset("lgd")
    for k in (1, 10, 50, 100):
        times = {"aps": [], "nplan": [], "splan": [], "fullscan": []}
        for q in ds.queries:
            qk = dataclasses.replace(q, k=k)
            for name, eng in (
                    ("aps", StreakEngine(ds.store)),
                    ("nplan", StreakEngine(ds.store, ExecConfig(force_plan="N"))),
                    ("splan", StreakEngine(ds.store, ExecConfig(force_plan="S"))),
                    ("fullscan", FullScanEngine(ds.store))):
                times[name].append(
                    common.timeit(lambda e=eng, qq=qk: e.execute(qq),
                                  warmup=1, repeat=1))
        for name, ts in times.items():
            gm = float(np.exp(np.mean(np.log(np.maximum(ts, 1.0)))))
            rows.append(common.row(f"fig12_varyk/lgd/k{k}_{name}", gm, ""))
    return rows
