"""Cell builder: (arch, shape, mesh) -> (step_fn, sharded abstract inputs).

Every one of the 40 assigned (architecture x input-shape) cells is realized
here as a jittable step function plus ShapeDtypeStruct inputs carrying
NamedShardings (weak-type-correct, shardable, zero allocation). The dry-run
lowers + compiles each cell; training/serving drivers reuse the same
builders with real arrays.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import registry
from ..dist import partitioning as pt
from ..models import equivariant, gnn, graphcast, moe, sasrec, transformer
from ..serve import retrieval
from ..train import optim


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: object                # jittable
    args: tuple               # ShapeDtypeStructs with shardings
    out_shardings: object = None
    donate: tuple = ()        # arg indices donated (in-place aliasing)
    static: dict = dataclasses.field(default_factory=dict)


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _shard_tree(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree, sharding_tree)


def _opt_sharding_like(param_sharding, mesh, abstract_params=None,
                       zero1: bool = True):
    rep = NamedSharding(mesh, P())
    if zero1 and abstract_params is not None:
        moments = pt.zero1_sharding(param_sharding, abstract_params, mesh)
    else:
        moments = param_sharding
    return {"m": moments, "v": moments, "step": rep}


def _abstract_params(init_fn, cfg):
    return jax.eval_shape(functools.partial(init_fn, cfg=cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# LM / MoE cells
# ---------------------------------------------------------------------------

def _lm_cell(arch, shape_name, spec, mesh, mod, cfg,
             variant: str = "base") -> Cell:
    is_moe = registry.get(arch).FAMILY == "moe"
    model_mod = moe if is_moe else transformer
    loss_fn = model_mod.lm_loss
    dp = pt.dp_axes(mesh)
    rep = NamedSharding(mesh, P())

    p_abs = _abstract_params(model_mod.init_params, cfg)
    p_shard = pt.lm_param_sharding(p_abs, mesh)
    params_in = _shard_tree(p_abs, p_shard)

    if spec["kind"] == "train":
        if variant == "base":
            # sequence-parallel residual stream (SP): the remat-saved
            # per-layer carry shards 16-way over "model"
            cfg = dataclasses.replace(cfg, batch_axes=dp, seq_axes=("model",))
            n_micro = 1
        elif variant == "opt":
            # iter 1: gradient-accumulation microbatching — small carries
            # without SP's per-layer activation all-gathers (TP all-reduces
            # replace them; see EXPERIMENTS.md §Perf)
            cfg = dataclasses.replace(cfg, batch_axes=dp, seq_axes=())
            n_micro = 4
        else:  # "opt2": SP + microbatching — small carries AND single
            #   grad sync; TP activation comms stay (inherent at TP=16)
            cfg = dataclasses.replace(cfg, batch_axes=dp, seq_axes=("model",))
            n_micro = 4
        o_abs = jax.eval_shape(optim.init_state, p_abs)
        o_shard = _opt_sharding_like(p_shard, mesh, p_abs)
        opt_in = _shard_tree(o_abs, o_shard)
        tokens = _sds((spec["global_batch"], spec["seq_len"] + 1), jnp.int32,
                      NamedSharding(mesh, P(dp, None)))
        ocfg = optim.AdamWConfig()

        def train_step(params, opt_state, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
            else:
                gb = batch.shape[0]
                mb = batch.reshape(n_micro, gb // n_micro, -1)

                def micro(gsum, tokens_):
                    l, g = jax.value_and_grad(loss_fn)(params, tokens_, cfg)
                    gsum = jax.tree.map(
                        lambda a, b2: a + b2.astype(jnp.float32), gsum, g)
                    return gsum, l
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                gsum, losses = jax.lax.scan(micro, zeros, mb)
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                loss = jnp.mean(losses)
            params, opt_state, metrics = optim.apply_updates(
                params, grads, opt_state, ocfg)
            return params, opt_state, loss, metrics

        return Cell(arch, shape_name, train_step,
                    (params_in, opt_in, tokens),
                    out_shardings=(p_shard, o_shard, rep, None),
                    donate=(0, 1))

    if spec["kind"] == "prefill":
        cfg2 = dataclasses.replace(cfg, attn_chunk=2048, remat=True)
        if variant == "opt":
            cfg2 = dataclasses.replace(cfg2, attn_bf16_operands=True)
        tokens = _sds((spec["global_batch"], spec["seq_len"]), jnp.int32,
                      NamedSharding(mesh, P(dp, None)))
        cache_shard = pt.kv_cache_sharding(mesh)

        def prefill(params, batch):
            return model_mod.forward_with_cache(params, batch, cfg2)

        return Cell(arch, shape_name, prefill, (params_in, tokens),
                    out_shardings=(None, {"k": cache_shard, "v": cache_shard}))

    # decode kinds -----------------------------------------------------
    if variant == "opt":
        # bf16 cache reads with f32 MXU accumulation + scatter cache update
        cfg = dataclasses.replace(cfg, attn_bf16_operands=True,
                                  scatter_cache_update=True)
    b, s = spec["global_batch"], spec["seq_len"]
    if b == 1:  # long-context: batch unshardable, spread seq over everything
        cache_spec = NamedSharding(mesh, P(None, None, dp + ("model",),
                                           None, None))
        tok_spec = NamedSharding(mesh, P(None))
    else:
        cache_spec = pt.kv_cache_sharding(mesh)
        tok_spec = NamedSharding(mesh, P(dp))
    cache_abs = jax.eval_shape(
        lambda: model_mod.init_cache(cfg, b, s))
    cache_in = jax.tree.map(
        lambda a: _sds(a.shape, a.dtype, cache_spec), cache_abs)
    tokens = _sds((b,), jnp.int32, tok_spec)
    pos = _sds((b,), jnp.int32, tok_spec)

    def serve_step(params, cache, tok, pos):
        return model_mod.decode_step(params, cache, tok, pos, cfg)

    return Cell(arch, shape_name, serve_step,
                (params_in, cache_in, tokens, pos),
                out_shardings=(None, {"k": cache_spec, "v": cache_spec}),
                donate=(1,) if variant == "opt" else ())


# ---------------------------------------------------------------------------
# GNN cells (gcn / sage)
# ---------------------------------------------------------------------------

def _dp_size(mesh) -> int:
    return int(np_prod([mesh.shape[a] for a in pt.dp_axes(mesh)]))


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _gnn_sizes(spec, mesh) -> tuple[int, int]:
    """Node/edge counts padded to the data-axis size (loaders zero-pad;
    loss masks exclude padding)."""
    if spec["kind"] == "sampled":
        b = spec["batch_nodes"]
        f1, f2 = spec["fanout"]
        nodes = b * (1 + f1 + f1 * f2)
        edges = b * f1 + b * f1 * f2
    elif spec["kind"] == "batched":
        nodes = spec["n_nodes"] * spec["batch"]
        edges = spec["n_edges"] * spec["batch"]
    else:
        nodes, edges = spec["n_nodes"], spec["n_edges"]
    m = _dp_size(mesh)
    return _pad_to(nodes, m), _pad_to(edges, m)


def _gnn_cell(arch, shape_name, spec, mesh, cfg) -> Cell:
    n, e = _gnn_sizes(spec, mesh)
    cfg = dataclasses.replace(cfg, d_in=spec["d_feat"],
                              d_out=max(spec["n_classes"], 2))
    dp = pt.dp_axes(mesh)
    rep = NamedSharding(mesh, P())
    p_abs = _abstract_params(gnn.init_params, cfg)
    p_shard = pt.gnn_param_sharding(p_abs, mesh)
    params_in = _shard_tree(p_abs, p_shard)
    o_abs = jax.eval_shape(optim.init_state, p_abs)
    o_shard = _opt_sharding_like(p_shard, mesh, p_abs)
    opt_in = _shard_tree(o_abs, o_shard)
    x = _sds((n, spec["d_feat"]), jnp.float32, NamedSharding(mesh, P(dp, None)))
    edges = _sds((2, e), jnp.int32, NamedSharding(mesh, P(None, dp)))
    labels = _sds((n,), jnp.int32, NamedSharding(mesh, P(dp)))
    mask = _sds((n,), jnp.bool_, NamedSharding(mesh, P(dp)))
    ocfg = optim.AdamWConfig()

    def train_step(params, opt_state, x, edges, labels, mask):
        loss, grads = jax.value_and_grad(gnn.nll_loss)(
            params, x, edges, labels, mask, cfg)
        params, opt_state, metrics = optim.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, loss, metrics

    return Cell(arch, shape_name, train_step,
                (params_in, opt_in, x, edges, labels, mask),
                out_shardings=(p_shard, o_shard, rep, None))


# ---------------------------------------------------------------------------
# GraphCast cells
# ---------------------------------------------------------------------------

def _graphcast_cell(arch, shape_name, spec, mesh, cfg) -> Cell:
    n, e = _gnn_sizes(spec, mesh)
    n_mesh = max(n // 4, 16)
    n_bip = 4 * n
    dp = pt.dp_axes(mesh)
    rep = NamedSharding(mesh, P())
    p_abs = _abstract_params(graphcast.init_params, cfg)
    p_shard = pt.graphcast_param_sharding(p_abs, mesh)
    params_in = _shard_tree(p_abs, p_shard)
    o_abs = jax.eval_shape(optim.init_state, p_abs)
    o_shard = _opt_sharding_like(p_shard, mesh, p_abs)
    opt_in = _shard_tree(o_abs, o_shard)
    edge_spec = NamedSharding(mesh, P(None, dp))
    gx = _sds((n, cfg.n_vars), jnp.float32, NamedSharding(mesh, P(dp, None)))
    g2m = _sds((2, n_bip), jnp.int32, edge_spec)
    me = _sds((2, e), jnp.int32, edge_spec)
    m2g = _sds((2, n_bip), jnp.int32, edge_spec)
    target = _sds((n, cfg.n_vars), jnp.float32,
                  NamedSharding(mesh, P(dp, None)))
    ocfg = optim.AdamWConfig()

    def train_step(params, opt_state, gx, g2m, me, m2g, target):
        def loss_fn(p):
            return graphcast.mse_loss(p, gx, target, g2m, me, m2g, n_mesh, cfg)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = optim.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, loss, metrics

    return Cell(arch, shape_name, train_step,
                (params_in, opt_in, gx, g2m, me, m2g, target),
                out_shardings=(p_shard, o_shard, rep, None))


# ---------------------------------------------------------------------------
# NequIP cells
# ---------------------------------------------------------------------------

def _nequip_cell(arch, shape_name, spec, mesh, cfg) -> Cell:
    n, e = _gnn_sizes(spec, mesh)
    n_graphs = spec.get("batch", 1)
    dp = pt.dp_axes(mesh)
    rep = NamedSharding(mesh, P())
    p_abs = _abstract_params(equivariant.init_params, cfg)
    p_shard = jax.tree.map(lambda _: rep, p_abs)  # tiny weights: replicate
    params_in = _shard_tree(p_abs, p_shard)
    o_abs = jax.eval_shape(optim.init_state, p_abs)
    o_shard = _opt_sharding_like(p_shard, mesh, p_abs)
    opt_in = _shard_tree(o_abs, o_shard)
    species = _sds((n,), jnp.int32, NamedSharding(mesh, P(dp)))
    positions = _sds((n, 3), jnp.float32, NamedSharding(mesh, P(dp, None)))
    edges = _sds((2, e), jnp.int32, NamedSharding(mesh, P(None, dp)))
    gid = _sds((n,), jnp.int32, NamedSharding(mesh, P(dp)))
    targets = _sds((n_graphs,), jnp.float32, NamedSharding(mesh, P(None)))
    ocfg = optim.AdamWConfig()

    def train_step(params, opt_state, species, positions, edges, gid, targets):
        def loss_fn(p):
            return equivariant.batched_energy_loss(
                p, species, positions, edges, gid, targets, cfg)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = optim.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, loss, metrics

    return Cell(arch, shape_name, train_step,
                (params_in, opt_in, species, positions, edges, gid, targets),
                out_shardings=(p_shard, o_shard, rep, None))


# ---------------------------------------------------------------------------
# SASRec cells
# ---------------------------------------------------------------------------

def _sasrec_cell(arch, shape_name, spec, mesh, cfg,
                 variant: str = "base") -> Cell:
    dp = pt.dp_axes(mesh)
    rep = NamedSharding(mesh, P())
    p_abs = _abstract_params(sasrec.init_params, cfg)
    p_shard = pt.sasrec_param_sharding(p_abs, mesh)
    params_in = _shard_tree(p_abs, p_shard)

    if spec["kind"] == "train":
        o_abs = jax.eval_shape(optim.init_state, p_abs)
        o_shard = _opt_sharding_like(p_shard, mesh, p_abs)
        opt_in = _shard_tree(o_abs, o_shard)
        bshape = (spec["batch"], cfg.seq_len)
        bspec = NamedSharding(mesh, P(dp, None))
        seq = _sds(bshape, jnp.int32, bspec)
        pos_i = _sds(bshape, jnp.int32, bspec)
        neg_i = _sds(bshape, jnp.int32, bspec)
        ocfg = optim.AdamWConfig()

        def train_step(params, opt_state, seq, pos_i, neg_i):
            loss, grads = jax.value_and_grad(sasrec.bpr_loss)(
                params, seq, pos_i, neg_i, cfg)
            params, opt_state, metrics = optim.apply_updates(
                params, grads, opt_state, ocfg)
            return params, opt_state, loss, metrics

        return Cell(arch, shape_name, train_step,
                    (params_in, opt_in, seq, pos_i, neg_i),
                    out_shardings=(p_shard, o_shard, rep, None))

    if spec["kind"] in ("serve", "bulk"):
        b = spec["batch"]
        seq = _sds((b, cfg.seq_len), jnp.int32, NamedSharding(mesh, P(dp, None)))
        user_chunk = 512 if spec["kind"] == "bulk" else b

        def serve_step(params, seq):
            state = sasrec.user_state(params, seq, cfg)
            if variant == "opt":
                # catalog stays sharded: shard-local scans + k-wide merge
                scorer = lambda st: retrieval.blocked_topk_sharded(
                    st, params["item_embed"], mesh=mesh, axis="model",
                    k=100, block=131072)
            else:
                scorer = lambda st: retrieval.blocked_topk(
                    st, params["item_embed"], k=100, block=131072)
            if user_chunk < b:
                states = state.reshape(b // user_chunk, user_chunk, -1)
                return jax.lax.map(scorer, states)
            return scorer(state)

        return Cell(arch, shape_name, serve_step, (params_in, seq))

    # retrieval_cand: 1 query x 1M candidates through STREAK early-out top-k
    n_items = cfg.n_items
    block = 65536
    nb = -(-n_items // block)
    seq = _sds((spec["batch"], cfg.seq_len), jnp.int32,
               NamedSharding(mesh, P(None, None)))
    items_sorted = _sds((n_items, cfg.embed_dim), jnp.float32,
                        NamedSharding(mesh, P("model", None)))
    item_order = _sds((n_items,), jnp.int32, NamedSharding(mesh, P("model")))
    if variant == "opt":
        # shard-local early-out scans + one k-wide merge (no per-block
        # all-gather of the catalog); bounds sharded with their blocks
        bounds = _sds((nb,), jnp.float32, NamedSharding(mesh, P("model")))

        def retrieval_step(params, seq, items_sorted, item_order, bounds):
            state = sasrec.user_state(params, seq, cfg)
            return retrieval.streak_topk_sharded(
                state, items_sorted, item_order, bounds, mesh=mesh,
                axis="model", k=100, block=block)
    else:
        bounds = _sds((nb,), jnp.float32, rep)

        def retrieval_step(params, seq, items_sorted, item_order, bounds):
            state = sasrec.user_state(params, seq, cfg)
            return retrieval.streak_topk(state, items_sorted, item_order,
                                         bounds, k=100, block=block)

    return Cell(arch, shape_name, retrieval_step,
                (params_in, seq, items_sorted, item_order, bounds))


# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh,
               variant: str = "base") -> Cell:
    mod = registry.get(arch)
    spec = mod.SHAPES[shape_name]
    cfg = mod.CONFIG
    fam = mod.FAMILY
    if fam in ("lm", "moe"):
        return _lm_cell(arch, shape_name, spec, mesh, mod, cfg, variant)
    if fam == "gnn":
        return _gnn_cell(arch, shape_name, spec, mesh, cfg)
    if fam == "graphcast":
        return _graphcast_cell(arch, shape_name, spec, mesh, cfg)
    if fam == "nequip":
        return _nequip_cell(arch, shape_name, spec, mesh, cfg)
    if fam == "recsys":
        return _sasrec_cell(arch, shape_name, spec, mesh, cfg, variant)
    raise ValueError(fam)
