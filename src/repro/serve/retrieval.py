"""STREAK top-k retrieval as a serving primitive.

The paper's ORDER BY ... LIMIT machinery (block-wise scoring, per-block upper
bounds, threshold early termination) applied to candidate scoring:

- `blocked_topk`      : lax.scan over item blocks, carrying a running top-k —
                        the fixed "S-Plan-like" full scan (offline bulk path).
- `streak_topk`       : lax.while_loop with the threshold test — blocks are
                        pre-sorted by their score UPPER BOUND (block_max of
                        ||e_i|| — a Cauchy-Schwarz bound, the exact analogue
                        of the paper's numeric-index block_max), and the loop
                        stops at the first block whose bound cannot beat
                        theta. This is the paper's N-Plan early termination.

Both are exact (return the true top-k); `streak_topk` simply reads fewer
blocks. Used by the sasrec serve_p99 / serve_bulk / retrieval_cand cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map across versions; see launch/mesh.shard_map_compat."""
    from ..launch.mesh import shard_map_compat
    return shard_map_compat(f, mesh, in_specs, out_specs)


def _merge_topk(scores, ids, new_scores, new_ids, k):
    s = jnp.concatenate([scores, new_scores], axis=-1)
    i = jnp.concatenate([ids, new_ids], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def blocked_topk(state: jnp.ndarray, items: jnp.ndarray, k: int = 100,
                 block: int = 65536):
    """state (B, D) x items (N, D) -> (scores (B, k), ids (B, k)).

    Full blocked scan: every item block is scored; memory stays at
    (B, block) instead of (B, N).
    """
    b, d = state.shape
    n = items.shape[0]
    nb = -(-n // block)
    npad = nb * block
    items_p = jnp.pad(items, ((0, npad - n), (0, 0)))
    items_b = items_p.reshape(nb, block, d)

    def body(carry, xs):
        scores, ids = carry
        blk_idx, blk = xs
        s = state @ blk.T                                   # (B, block)
        base = blk_idx * block
        cand_ids = base + jnp.arange(block, dtype=jnp.int32)
        s = jnp.where(cand_ids[None, :] < n, s, -jnp.inf)
        scores, ids = _merge_topk(scores, ids,
                                  s, jnp.broadcast_to(cand_ids, s.shape), k)
        return (scores, ids), None

    init = (jnp.full((b, k), -jnp.inf, state.dtype),
            jnp.zeros((b, k), jnp.int32))
    (scores, ids), _ = jax.lax.scan(
        body, init, (jnp.arange(nb, dtype=jnp.int32), items_b))
    return scores, ids


def block_bounds(items: jnp.ndarray, block: int) -> jnp.ndarray:
    """Per-block score upper-bound material: max ||item|| per block."""
    n, d = items.shape
    nb = -(-n // block)
    items_p = jnp.pad(items, ((0, nb * block - n), (0, 0)))
    norms = jnp.sqrt(jnp.sum(items_p * items_p, axis=-1))
    return norms.reshape(nb, block).max(axis=1)            # (nb,)


def sort_items_by_norm(items: jnp.ndarray, block: int):
    """Reorder the catalog by descending norm so block bounds decrease —
    the analogue of STREAK's value-sorted numeric index (build-time step)."""
    norms = jnp.sqrt(jnp.sum(items * items, axis=-1))
    order = jnp.argsort(-norms)
    return items[order], order


@functools.partial(jax.jit, static_argnames=("k", "block"))
def streak_topk(state: jnp.ndarray, items_sorted: jnp.ndarray,
                item_order: jnp.ndarray, bounds: jnp.ndarray,
                k: int = 100, block: int = 65536):
    """Early-terminating top-k over a norm-sorted catalog.

    state (B, D); items_sorted (N, D) descending-norm; bounds (nb,).
    Stops at the first block where ||state|| * bound <= theta (the k-th best
    score so far) — no later block can contribute (Cauchy-Schwarz), exactly
    the paper's threshold test against the numeric block_max.
    """
    b, d = state.shape
    n = items_sorted.shape[0]
    nb = bounds.shape[0]
    items_b = jnp.pad(items_sorted, ((0, nb * block - n), (0, 0))) \
        .reshape(nb, block, d)
    state_norm = jnp.sqrt(jnp.sum(state * state, axis=-1))   # (B,)

    def cond(carry):
        bi, scores, ids = carry
        theta = scores[:, -1]                                # (B,) k-th best
        can_improve = (state_norm * bounds[jnp.minimum(bi, nb - 1)]
                       > theta).any()
        return (bi < nb) & can_improve

    def body(carry):
        bi, scores, ids = carry
        blk = jax.lax.dynamic_index_in_dim(items_b, bi, 0, keepdims=False)
        s = state @ blk.T
        base = bi * block
        cand = base + jnp.arange(block, dtype=jnp.int32)
        s = jnp.where(cand[None, :] < n, s, -jnp.inf)
        real_ids = item_order[jnp.clip(cand, 0, n - 1)].astype(jnp.int32)
        scores, ids = _merge_topk(scores, ids, s,
                                  jnp.broadcast_to(real_ids, s.shape), k)
        return bi + 1, scores, ids

    # inits derive from `state` (zero-valued add) so that under shard_map the
    # carry inherits state's varying-axis type and matches the body output
    zero = jnp.zeros_like(state[:, :1])
    init = (jnp.int32(0),
            jnp.full((b, k), -jnp.inf, state.dtype) + zero,
            jnp.zeros((b, k), jnp.int32) + zero.astype(jnp.int32))
    bi, scores, ids = jax.lax.while_loop(cond, body, init)
    return scores, ids, bi   # bi = blocks actually read (early-out metric)


def streak_topk_sharded(state, items_sorted, item_order, bounds,
                        mesh, axis: str = "model", k: int = 100,
                        block: int = 65536):
    """Expert-parallel STREAK retrieval: each `axis` shard runs the
    early-terminating scan over its local (norm-interleaved) block set, then
    one k-wide all-gather merges shard-local top-k — no per-block
    all-gathers of the catalog (the baseline's dominant collective).

    Blocks should be dealt round-robin across shards (data prep) so every
    shard sees the same bound profile and early-out fires uniformly.
    """
    from jax.sharding import PartitionSpec as P

    def local(state_, items_, order_, bounds_):
        # mark the (replicated) query state shard-varying so the while-loop
        # carry typing matches the shard-local block scan
        if hasattr(jax.lax, "pcast"):
            state_ = jax.lax.pcast(state_, (axis,), to="varying")
        else:  # zero-valued data dependency on a shard-local array
            state_ = state_ + 0.0 * items_.ravel()[0]
        scores, ids, bi = streak_topk(state_, items_, order_, bounds_,
                                      k=k, block=block)
        all_s = jax.lax.all_gather(scores, axis, axis=1)   # (B, n, k)
        all_i = jax.lax.all_gather(ids, axis, axis=1)
        b = all_s.shape[0]
        top_s, pos = jax.lax.top_k(all_s.reshape(b, -1), k)
        top_i = jnp.take_along_axis(all_i.reshape(b, -1), pos, axis=-1)
        return top_s, top_i, jax.lax.pmax(bi, axis)

    # replication checks off: outputs ARE replicated (all_gather +
    # deterministic top_k) but the varying-axis inference cannot prove it
    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis), P(axis)),
        out_specs=(P(), P(), P()))(state, items_sorted, item_order, bounds)


def blocked_topk_sharded(state, items, mesh, axis: str = "model",
                         k: int = 100, block: int = 65536):
    """Catalog-sharded bulk scoring: each `axis` shard scans ITS item rows
    (no per-block catalog all-gather), then one k-wide merge. The offline
    serve_bulk path: kills the baseline's dominant collective term."""
    from jax.sharding import PartitionSpec as P
    n = items.shape[0]
    shards = mesh.shape[axis]
    base = jnp.arange(0, n, n // shards, dtype=jnp.int32)[:shards]

    def local(state_, items_, offset_):
        if hasattr(jax.lax, "pcast"):
            state_ = jax.lax.pcast(state_, (axis,), to="varying")
        else:
            state_ = state_ + 0.0 * items_.ravel()[0]
        scores, ids = blocked_topk(state_, items_, k=k,
                                   block=min(block, items_.shape[0]))
        ids = ids + offset_[0]
        all_s = jax.lax.all_gather(scores, axis, axis=1)
        all_i = jax.lax.all_gather(ids, axis, axis=1)
        b = all_s.shape[0]
        top_s, pos = jax.lax.top_k(all_s.reshape(b, -1), k)
        top_i = jnp.take_along_axis(all_i.reshape(b, -1), pos, axis=-1)
        return top_s, top_i

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(), P()))(state, items, base)
