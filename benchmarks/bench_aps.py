"""Fig. 9: APS adaptive plan selection vs fixed N-Plan / S-Plan.

APS should track min(N, S) per query and sometimes beat both by switching
mid-query as theta tightens.
"""
from __future__ import annotations

from repro import ExecConfig, StreakEngine

from . import common


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            engines = {
                "aps": StreakEngine(ds.store, ExecConfig()),
                "nplan": StreakEngine(ds.store, ExecConfig(force_plan="N")),
                "splan": StreakEngine(ds.store, ExecConfig(force_plan="S")),
            }
            times = {}
            for name, eng in engines.items():
                times[name] = common.timeit(lambda e=eng: e.execute(q))
            _, _, st = engines["aps"].execute(q)
            plans = f"N{st.plan_n}/S{st.plan_s}"
            best_fixed = min(times["nplan"], times["splan"])
            for name in ("aps", "nplan", "splan"):
                derived = (f"plans={plans};vs_best_fixed="
                           f"{times[name]/max(best_fixed,1):.2f}x"
                           if name == "aps" else "")
                rows.append(common.row(
                    f"fig9_aps/{ds_name}/Q{qi+1}_{name}", times[name],
                    derived))
    return rows
