"""Multi-tenant serving: 8 concurrent lgd queries through the slot-based
admission loop vs serial per-query execution.

Two request mixes bracket the serving layer's win:

- ``hotq``: 8 tenants all running the hot lgd query shape with per-tenant
  ``k`` — the classic serving workload (many users, one popular query).
  Cross-tenant sharing (driver-block materialization, S/N-Plan retrieval,
  pooled+deduped SIP rows, MBR pairs, refine verdicts — all θ-independent,
  hence bit-exact) collapses the redundant per-tenant work; this is the
  headline ≥2x row.
- ``mixed``: the 8 distinct lgd query shapes with mixed ``k`` — no
  cross-tenant redundancy to harvest, so this isolates the pure
  batching/scheduling overhead of the serve loop (must stay ~parity).

And two serial baselines per mix:

- ``serial_perquery``: a fresh StreakEngine per query — the deployment
  without a serving layer (per-request engine instantiation, no shared
  caches, no cross-query batching). This is the headline comparison.
- ``serial_warm``: one shared engine executing the batch back-to-back with
  hot caches — the upper bound a perfectly warmed sequential executor can
  reach without the serving layer's cross-tenant sharing.

Every run asserts per-query results are bit-identical to serial execution.

A third ``faulted`` row (fused config) re-runs the serve batch under a
seeded 1% fault-injection plan at the kernel dispatch seam: the failover
chains must absorb every injected failure with zero result drift, and the
``throughput_ratio_vs_fault_free`` derived metric tracks the recovery
overhead (the acceptance floor is 0.8).

Standalone: ``python -m benchmarks.bench_serve --json`` writes
``BENCH_serve.json`` (the artifact CI uploads).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import BackendPolicy, ExecConfig, StreakEngine
from repro.core import fault
from repro.serve.spatial import SpatialServeEngine

from . import common

N_CONCURRENT = 8
MAX_SLOTS = 8
KS = (5, 10, 20, 40, 60, 80, 100, 120)   # per-tenant k mix

CONFIGS = {
    "numpy": ExecConfig(),
    "fused": ExecConfig(policy=BackendPolicy(join="fused", kcap="auto")),
}


def _mixes(ds) -> dict:
    return {
        "hotq": [dataclasses.replace(ds.queries[0], k=k) for k in KS],
        "mixed": [dataclasses.replace(q, k=k)
                  for q, k in zip(ds.queries, KS)],
    }


def _assert_identical(reqs, serial) -> None:
    for req, (scores, rows, _) in zip(reqs, serial):
        assert req.done
        np.testing.assert_array_equal(req.scores, scores)
        assert req.rows.n == rows.n


def run() -> list:
    ds = common.dataset("lgd")
    rows = []
    for mname, queries in _mixes(ds).items():
        for cname, cfg in CONFIGS.items():
            # ---- serial baselines ---------------------------------------
            def serial_perquery():
                return [StreakEngine(ds.store, cfg).execute(q)
                        for q in queries]

            serial = serial_perquery()                   # also warms jit
            t_cold = common.timeit(serial_perquery, warmup=0, repeat=3)
            warm_eng = StreakEngine(ds.store, cfg)
            t_warm = common.timeit(
                lambda: [warm_eng.execute(q) for q in queries])

            # ---- serving loop (fresh serve engine per repeat: a batch of
            # 8 arriving tenants, caches shared only within the batch) -----
            def serve_batch():
                srv = SpatialServeEngine(ds.store, cfg, max_slots=MAX_SLOTS)
                return srv, srv.serve(queries)

            srv, reqs = serve_batch()             # warm + correctness check
            _assert_identical(reqs, serial)
            assert srv.stats.slot_reuse >= 0 and srv.stats.sip_batches > 0
            t_srv = common.timeit(lambda: serve_batch()[1])

            qps = N_CONCURRENT / (t_srv / 1e6)
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_batched_{N_CONCURRENT}q", t_srv,
                f"speedup_vs_serial_perquery={t_cold / max(t_srv, 1):.2f}x"
                f";speedup_vs_serial_warm={t_warm / max(t_srv, 1):.2f}x"
                f";qps={qps:.1f};bit_identical=true"))
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_serial_perquery"
                f"_{N_CONCURRENT}q", t_cold, ""))
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_serial_warm"
                f"_{N_CONCURRENT}q", t_warm, ""))

            if cname != "fused":
                continue
            # ---- fault-injected serving: seeded 1% failures at the kernel
            # dispatch seam; failover absorbs them bit-identically and the
            # throughput ratio vs the fault-free run tracks the overhead ---
            def serve_faulted():
                fault.STATE.reset()
                # seed picked so the 1% rate actually lands hits in both
                # mixes' dispatch streams (hotq makes only ~65 op calls)
                fault.install_plan(fault.FaultPlan(rate=0.01, seed=8))
                try:
                    srv = SpatialServeEngine(ds.store, cfg,
                                             max_slots=MAX_SLOTS)
                    reqs = srv.serve(queries)
                    return srv, reqs, fault.STATE.plan.injected
                finally:
                    fault.STATE.reset()

            fsrv, freqs, injected = serve_faulted()
            assert injected > 0, "1% plan must actually fire at bench scale"
            assert all(r.error is None for r in freqs)
            _assert_identical(freqs, serial)
            t_fault = common.timeit(lambda: serve_faulted()[1])
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_batched_{N_CONCURRENT}q_faulted",
                t_fault,
                f"injected={injected}"
                f";throughput_ratio_vs_fault_free="
                f"{t_srv / max(t_fault, 1):.2f}"
                f";bit_identical=true"))
    return rows


def main() -> None:
    import json
    import sys
    print("name,us_per_call,derived")
    out = []
    for r in run():
        print(r)
        name, us, derived = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    if "--json" in sys.argv[1:]:
        with open("BENCH_serve.json", "w") as fh:
            json.dump(out, fh, indent=1)
        print("# wrote BENCH_serve.json", file=sys.stderr)


if __name__ == "__main__":
    main()
