"""Quickstart: build a spatially-enriched RDF store and run a top-k
spatial-join SPARQL query through STREAK.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import (ExecConfig, Query, Ranking, SpatialFilter,
                   StreakEngine, TriplePattern, Var, build_store)
from repro.core.dictionary import Dictionary


def build_demo():
    """The quickstart store + query (also the fused-backend test workload)."""
    # --- tiny knowledge graph: wine regions + rivers (paper Fig. 1) -----
    d = Dictionary.empty()
    T = d.intern
    quads, geoms, exact = [], {}, {}
    rng = np.random.default_rng(0)

    fact = [0]

    def add(s, p, o, reified=False):
        g = T(f"_:f{fact[0]}") if reified else 0
        fact[0] += 1
        quads.append((g, s, p, o))
        return g

    has_geom, production, pollution = T("hasGeometry"), T("hasProduction"), \
        T("concentration")
    grape, soil = T("grapeVariety"), T("soilType")
    for i in range(40):  # wine regions in the west
        e = T(f"region{i}")
        xy = rng.uniform([0, 0], [40, 100])
        geoms[e] = [*xy, *xy]
        exact[e] = xy[None, :]
        add(e, has_geom, T(f"geo:r{i}"))
        add(e, grape, T(f"variety{i % 5}"))
        add(e, soil, T(f"soil{i % 3}"))
        add(e, production, d.intern_numeric(float(rng.lognormal(3, 1))))
    for i in range(40):  # rivers everywhere
        e = T(f"river{i}")
        xy = rng.uniform([0, 0], [100, 100])
        geoms[e] = [*xy, *xy]
        exact[e] = xy[None, :]
        add(e, has_geom, T(f"geo:v{i}"))
        add(e, T("hasMouth"), T(f"sea{i % 4}"))
        add(e, pollution, d.intern_numeric(float(rng.exponential(2.0))))

    store = build_store(np.array(quads, dtype=np.int64), d,
                        geometry_predicate=has_geom, geometries=geoms,
                        exact_geoms=exact, block=16, l_max=6)

    # --- "top wine regions near polluted rivers" ------------------------
    q = Query(
        select=(Var("region"), Var("river")),
        patterns=(
            TriplePattern(Var("region"), store.dictionary.term_to_id["grapeVariety"], Var("v")),
            TriplePattern(Var("region"), store.dictionary.term_to_id["hasProduction"], Var("p")),
            TriplePattern(Var("region"), store.dictionary.term_to_id["hasGeometry"], Var("g1")),
            TriplePattern(Var("river"), store.dictionary.term_to_id["hasMouth"], Var("m")),
            TriplePattern(Var("river"), store.dictionary.term_to_id["concentration"], Var("c")),
            TriplePattern(Var("river"), store.dictionary.term_to_id["hasGeometry"], Var("g2")),
        ),
        spatial=SpatialFilter(Var("g1"), Var("g2"), dist=25.0),
        ranking=Ranking(((Var("p"), 1.0), (Var("c"), 1.0)), descending=True),
        k=5)
    return store, q


def main() -> None:
    store, q = build_demo()
    engine = StreakEngine(store, ExecConfig(block=16))
    scores, rows, stats = engine.execute(q)
    print("top-5 (production + pollution, within 25km):")
    for i in range(len(scores)):
        r = store.dictionary.lookup(rows["region"][i])
        v = store.dictionary.lookup(rows["river"][i])
        print(f"  {r:>10s} x {v:<10s} score={scores[i]:8.2f}")
    print(f"\ndriver blocks: {stats.driver_blocks}, plans N/S: "
          f"{stats.plan_n}/{stats.plan_s}, early-terminated: "
          f"{stats.early_terminated}")


if __name__ == "__main__":
    main()
