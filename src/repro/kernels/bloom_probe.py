"""Pallas TPU kernel: batched Bloom-filter membership probes.

Phase-1 candidate search probes |frontier| x |driven CS| keys against
per-node Bloom filters. The filter rows are gathered once by the wrapper
(XLA gather); the kernel is pure 32-bit integer math: double hashing
(h1 + i*h2) mod nbits, word selection by one-hot reduction over the W lane
dimension (no in-row gather on TPU), and a bit test per probe.

Block layout: (bb, W) uint32 filter rows + (bb, 1) key halves per tile; all
buffers are VMEM-resident and lane-aligned for W in {8, 16, 32}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix32(x, seed: int):
    x = (x + jnp.uint32(0x9E3779B9) * jnp.uint32(seed + 1)).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = (x * jnp.uint32(0x85EBCA6B)).astype(jnp.uint32)
    x = x ^ (x >> 13)
    x = (x * jnp.uint32(0xC2B2AE35)).astype(jnp.uint32)
    x = x ^ (x >> 16)
    return x


def _hash32(lo, hi, seed: int):
    return _mix32(lo ^ _mix32(hi, seed + 7), seed)


def _kernel(bits_ref, lo_ref, hi_ref, out_ref, *, k: int):
    bits = bits_ref[...]                       # (bb, W) uint32
    lo = lo_ref[...].astype(jnp.uint32)        # (bb, 1)
    hi = hi_ref[...].astype(jnp.uint32)
    w_iota = jax.lax.broadcasted_iota(jnp.int32, bits.shape, 1)
    nbits = bits.shape[1] * 32
    h1 = _hash32(lo[:, 0], hi[:, 0], 0)
    h2 = _hash32(lo[:, 0], hi[:, 0], 1) | jnp.uint32(1)
    hit = jnp.ones(bits.shape[0], dtype=jnp.uint32)
    for i in range(k):
        pos = (h1 + jnp.uint32(i) * h2) % jnp.uint32(nbits)
        w = (pos // 32).astype(jnp.int32)
        shift = pos % 32
        sel = jnp.sum(bits * (w_iota == w[:, None]).astype(jnp.uint32), axis=1)
        hit = hit & ((sel >> shift) & jnp.uint32(1))
    out_ref[...] = hit[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "bb", "interpret"))
def bloom_probe(bits: jnp.ndarray, key_lo: jnp.ndarray, key_hi: jnp.ndarray,
                k: int = 3, bb: int = 1024,
                interpret: bool = False) -> jnp.ndarray:
    """bits (B, W) uint32 pre-gathered rows; keys split in 32-bit halves.

    Returns (B,) int32 (1 = all k bits set).
    """
    b, w = bits.shape
    bp = -(-b // bb) * bb
    bits_p = jnp.pad(bits, ((0, bp - b), (0, 0)))
    lo_p = jnp.pad(key_lo.astype(jnp.int32).reshape(-1, 1), ((0, bp - b), (0, 0)))
    hi_p = jnp.pad(key_hi.astype(jnp.int32).reshape(-1, 1), ((0, bp - b), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, w), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        interpret=interpret,
    )(bits_p, lo_p, hi_p)
    return out[:b, 0]
