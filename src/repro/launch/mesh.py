"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch sharding and carries the cross-pod
gradient all-reduce (optionally int8-compressed, dist/grad_compression.py).

Defined as a function so importing this module never touches jax device
state (the dry-run forces 512 host devices BEFORE any jax import).
"""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    """axis_types only exists on newer jax; omit it where absent."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real local devices (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_kwargs(2))


def make_shard_mesh(n_shards: int):
    """1-axis ``("shard",)`` mesh for the Morton-prefix store shards.

    Sized to the largest divisor of `n_shards` that fits the local device
    count, so a stacked ``(S, ...)`` per-shard batch partitions evenly —
    each device sweeps its resident shards with `lax.map` when S exceeds
    the device count (CI's shardlane forces 8 host devices via XLA_FLAGS).
    """
    n = len(jax.devices())
    d = max(k for k in range(1, min(n, n_shards) + 1) if n_shards % k == 0)
    return jax.make_mesh((d,), ("shard",), **_axis_kwargs(1))


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across versions (older jax: experimental, check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)
