"""Real-format ingestion: GTFS-flavored POI CSVs -> QuadStore.

The synthetic generators (synth_rdf.py) exercise the engine at scale but
every value in them is drawn from a distribution the tests control. This
module ingests the shape of data Geographica-style workloads actually start
from — a `stops.txt`-like CSV of POIs with ids, names, lat/lon coordinates
and numeric attribute columns — and assembles the same `QuadStore` the
synthetic path builds, so every query shape (top-k join, range, within,
kNN, spatial join) runs on it unchanged:

- each row becomes an entity `stop:<stop_id>` with a POINT geometry at
  (lon, lat) — world x = longitude, y = latitude, the GeoSPARQL axis order;
- a reified ``rdf:type gtfs:Stop`` fact carries the row order as a
  confidence stand-in only when no numeric column exists;
- every extra column that parses as a float on every non-empty row becomes
  a numeric predicate ``gtfs:<column>`` with interned numeric literals —
  i.e. a rankable predicate with a directed numeric index, usable in
  ``ORDER BY`` rankings exactly like the synthetic ``hasConfidence``;
- non-numeric extra columns become plain string-object predicates.

Blank cells skip the quad (SPARQL open-world: the row simply has no such
fact), which also exercises the engine's NaN-score drop path when such a
column is used for ranking.
"""
from __future__ import annotations

import csv
import dataclasses
import io

import numpy as np

from ..core.dictionary import Dictionary
from ..core.store import QuadStore, build_store

REQUIRED_COLUMNS = ("stop_id", "stop_name", "stop_lat", "stop_lon")


@dataclasses.dataclass
class IngestedDataset:
    """A CSV ingested into a queryable store.

    ns maps every predicate/class term used during ingestion to its
    (post-tree-build, spatial) dictionary id; numeric_columns lists the
    CSV columns that became rankable predicates.
    """
    store: QuadStore
    ns: dict
    n_stops: int
    numeric_columns: tuple
    string_columns: tuple


def parse_stops_csv(source) -> list[dict]:
    """Parse a GTFS-stops-flavored CSV into row dicts.

    `source` is a filesystem path or an already-open text stream. The four
    GTFS-required columns must be present; every other column rides along
    verbatim (classification into numeric/string happens at quad-build
    time, over the whole column). Raises ValueError on missing required
    columns, unparseable coordinates, or duplicate stop_ids.
    """
    if hasattr(source, "read"):
        rows = list(csv.DictReader(source))
    else:
        with open(source, newline="") as fh:
            rows = list(csv.DictReader(fh))
    if not rows:
        raise ValueError("empty stops CSV")
    missing = [c for c in REQUIRED_COLUMNS if c not in rows[0]]
    if missing:
        raise ValueError(f"stops CSV missing required columns: {missing}")
    seen: set = set()
    for i, row in enumerate(rows):
        sid = (row["stop_id"] or "").strip()
        if not sid:
            raise ValueError(f"row {i}: empty stop_id")
        if sid in seen:
            raise ValueError(f"row {i}: duplicate stop_id {sid!r}")
        seen.add(sid)
        try:
            row["stop_lat"] = float(row["stop_lat"])
            row["stop_lon"] = float(row["stop_lon"])
        except (TypeError, ValueError):
            raise ValueError(f"row {i} ({sid}): unparseable coordinates")
    return rows


def parse_stops_text(text: str) -> list[dict]:
    """`parse_stops_csv` over an in-memory CSV string (tests, fixtures)."""
    return parse_stops_csv(io.StringIO(text))


def _classify_columns(rows: list[dict]) -> tuple[list, list]:
    """Split extra columns into numeric (every non-empty cell parses as a
    float, at least one non-empty cell) and string columns."""
    extras = [c for c in rows[0] if c not in REQUIRED_COLUMNS]
    numeric, string = [], []
    for c in extras:
        cells = [(r.get(c) or "").strip() for r in rows]
        filled = [v for v in cells if v]
        if filled:
            try:
                for v in filled:
                    float(v)
                numeric.append(c)
                continue
            except ValueError:
                pass
            string.append(c)
    return numeric, string


def build_stops_store(source, l_max: int = 8, leaf_capacity: int = 64,
                      block: int = 256) -> IngestedDataset:
    """Ingest a stops CSV (path, stream, or pre-parsed row list) into a
    QuadStore with geometries, characteristic sets, and numeric indexes."""
    rows = source if isinstance(source, list) else parse_stops_csv(source)
    numeric_cols, string_cols = _classify_columns(rows)

    d = Dictionary.empty()
    names = ["rdf:type", "gtfs:Stop", "gtfs:name", "hasGeometry",
             "hasConfidence"]
    names += [f"gtfs:{c}" for c in numeric_cols + string_cols]
    ns = {t: d.intern(t) for t in names}

    quads: list[tuple[int, int, int, int]] = []
    geoms: dict = {}
    exact: dict = {}
    fact_n = 0
    for i, row in enumerate(rows):
        e = d.intern(f"stop:{row['stop_id'].strip()}")
        geo = d.intern(f"geom:stop:{row['stop_id'].strip()}")
        x, y = float(row["stop_lon"]), float(row["stop_lat"])
        geoms[e] = (x, y, x, y)
        exact[e] = np.array([[x, y]], dtype=np.float64)
        g = d.intern(f"_:stopfact{fact_n}")
        fact_n += 1
        quads.append((g, e, ns["rdf:type"], ns["gtfs:Stop"]))
        if not numeric_cols:
            # no rankable column in the file: row order as a stand-in so
            # top-k queries stay expressible
            quads.append((0, g, ns["hasConfidence"],
                          d.intern_numeric(float(i) / max(len(rows), 1))))
        quads.append((0, e, ns["gtfs:name"],
                      d.intern(f"name:{(row['stop_name'] or '').strip()}")))
        quads.append((0, e, ns["hasGeometry"], geo))
        for c in numeric_cols:
            v = (row.get(c) or "").strip()
            if v:
                quads.append((0, e, ns[f"gtfs:{c}"],
                              d.intern_numeric(float(v))))
        for c in string_cols:
            v = (row.get(c) or "").strip()
            if v:
                quads.append((0, e, ns[f"gtfs:{c}"], d.intern(f"{c}:{v}")))

    store = build_store(np.array(quads, dtype=np.int64), d,
                        geometry_predicate=ns["hasGeometry"],
                        geometries=geoms, exact_geoms=exact,
                        l_max=l_max, leaf_capacity=leaf_capacity,
                        block=block)
    ns = {t: store.dictionary.term_to_id[t] for t in ns}
    return IngestedDataset(store=store, ns=ns, n_stops=len(rows),
                           numeric_columns=tuple(numeric_cols),
                           string_columns=tuple(string_cols))
