"""NequIP-style E(3)-equivariant interatomic potential [arXiv:2101.03164].

Features are direct sums of real-spherical-harmonic irreps l <= l_max with
`n_channels` channels each. Interaction blocks:

  message m_ij = sum_{l1,l2->l3} R_{path}(|r_ij|) * CG^{l1 l2 l3} h_j^{l1} Y^{l2}(r_ij)
  update  h_i' = h_i + Linear_l( scatter_sum_j m_ij )

with Bessel radial basis + MLP for R, and a norm gate for l > 0 channels.

Clebsch-Gordan coupling for REAL spherical harmonics is obtained numerically
as Gaunt coefficients T[a,b,c] = ∫ Y_{l1 a} Y_{l2 b} Y_{l3 c} dΩ via
Gauss-Legendre x uniform-phi quadrature (exact for the polynomial integrand
at these degrees) — provably SO(3)-equivariant by construction, no complex
phase conventions to get wrong. Equivariance is property-tested by energy
invariance under random rotations (tests/test_models.py).

Neighbor lists (cutoff graphs) come from the STREAK spatial index
(core.squadtree.radius_join) — the paper's distance join as a force-field
substrate.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from . import layers
from .layers import dense_init


# ---------------------------------------------------------------------------
# real spherical harmonics (unit vectors), l <= 2
# ---------------------------------------------------------------------------

def real_sph_harm(vec: jnp.ndarray, l: int) -> jnp.ndarray:
    """vec (..., 3) unit vectors -> (..., 2l+1)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    if l == 0:
        return jnp.full(vec.shape[:-1] + (1,), 0.28209479177387814,
                        dtype=vec.dtype)
    if l == 1:
        c = 0.4886025119029199
        return jnp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1, c2, c3 = 1.0925484305920792, 0.31539156525252005, 0.5462742152960396
        return jnp.stack([
            c1 * x * y, c1 * y * z, c2 * (3 * z * z - 1.0),
            c1 * x * z, c3 * (x * x - y * y)], axis=-1)
    raise NotImplementedError(f"l={l}")


def _real_sph_np(vec: np.ndarray, l: int) -> np.ndarray:
    """float64 numpy twin of real_sph_harm (quadrature-grade precision)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    if l == 0:
        return np.full(vec.shape[:-1] + (1,), 0.28209479177387814)
    if l == 1:
        c = 0.4886025119029199
        return np.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1, c2, c3 = 1.0925484305920792, 0.31539156525252005, 0.5462742152960396
        return np.stack([
            c1 * x * y, c1 * y * z, c2 * (3 * z * z - 1.0),
            c1 * x * z, c3 * (x * x - y * y)], axis=-1)
    raise NotImplementedError(f"l={l}")


@functools.lru_cache(maxsize=None)
def gaunt(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real Gaunt tensor (2l1+1, 2l2+1, 2l3+1) by exact quadrature."""
    n_theta, n_phi = 16, 33  # exact for total degree <= 2*16-1 / n_phi-1
    nodes, weights = np.polynomial.legendre.leggauss(n_theta)
    phi = np.arange(n_phi) * (2 * np.pi / n_phi)
    ct = nodes[:, None]
    st = np.sqrt(1 - ct ** 2)
    x = (st * np.cos(phi)[None, :]).ravel()
    y = (st * np.sin(phi)[None, :]).ravel()
    z = np.broadcast_to(ct, (n_theta, n_phi)).ravel()
    w = np.broadcast_to(weights[:, None] * (2 * np.pi / n_phi),
                        (n_theta, n_phi)).ravel()
    v = np.stack([x, y, z], axis=-1)
    y1 = _real_sph_np(v, l1)
    y2 = _real_sph_np(v, l2)
    y3 = _real_sph_np(v, l3)
    t = np.einsum("n,na,nb,nc->abc", w, y1, y2, y3)
    t[np.abs(t) < 1e-9] = 0.0
    nrm = np.linalg.norm(t)
    # parity-forbidden paths integrate to quadrature noise: return zeros, do
    # NOT normalize noise up to O(1)
    return (t / nrm if nrm > 1e-6 else np.zeros_like(t)).astype(np.float32)


def allowed_paths(l_max: int) -> list:
    """(l1, l2, l3) with |l1-l2| <= l3 <= l1+l2, parity-allowed, all <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                if (l1 + l2 + l3) % 2 == 0:  # real Gaunt parity selection
                    out.append((l1, l2, l3))
    return out


# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    n_channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    radial_hidden: int = 64
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        paths = len(allowed_paths(self.l_max))
        c = self.n_channels
        radial = self.n_rbf * self.radial_hidden \
            + self.radial_hidden * paths * c
        linear = (self.l_max + 1) * c * c
        per_layer = radial + linear
        return self.n_species * c + self.n_layers * per_layer + c * 1


def bessel_basis(r: jnp.ndarray, n: int, cutoff: float) -> jnp.ndarray:
    """Radial Bessel basis [DimeNet] with cosine cutoff envelope."""
    r = jnp.maximum(r, 1e-9)
    freqs = jnp.arange(1, n + 1, dtype=r.dtype) * jnp.pi / cutoff
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(freqs * r[..., None]) / r[..., None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / cutoff, 0, 1)) + 1.0)
    return rb * env[..., None]


def init_params(key, cfg: NequIPConfig):
    dt = cfg.jdtype
    c = cfg.n_channels
    paths = allowed_paths(cfg.l_max)
    ks = layers.split_keys(key, 3 * cfg.n_layers + 3)
    lyrs = []
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        lyrs.append({
            "radial_w1": dense_init(k1, (cfg.n_rbf, cfg.radial_hidden), dtype=dt),
            "radial_w2": dense_init(k2, (cfg.radial_hidden, len(paths) * c),
                                    dtype=dt),
            "mix": dense_init(k3, (cfg.l_max + 1, c, c), in_axis=1, dtype=dt),
        })
    return {
        "species_embed": dense_init(ks[-3], (cfg.n_species, c), dtype=dt),
        "layers": lyrs,
        "energy_head": dense_init(ks[-2], (c, 1), dtype=dt),
    }


def forward(params, species: jnp.ndarray, positions: jnp.ndarray,
            edges: jnp.ndarray, cfg: NequIPConfig) -> jnp.ndarray:
    """species (N,) int32, positions (N, 3), edges (2, E) -> energy scalar."""
    gid = jnp.zeros(species.shape[0], dtype=jnp.int32)
    return forward_batched(params, species, positions, edges, gid, 1, cfg)[0]


def forward_batched(params, species, positions, edges, graph_ids,
                    n_graphs: int, cfg: NequIPConfig) -> jnp.ndarray:
    """Per-graph energies for a block-diagonal batch of molecules.

    Identical message passing (edges never cross graphs by construction);
    the readout segment-sums atom energies by graph id -> (n_graphs,).
    """
    n = species.shape[0]
    src, dst = edges[0], edges[1]
    c = cfg.n_channels
    paths = allowed_paths(cfg.l_max)
    h = {0: params["species_embed"][species][:, :, None]}
    for l in range(1, cfg.l_max + 1):
        h[l] = jnp.zeros((n, c, 2 * l + 1), cfg.jdtype)
    rvec = positions[dst] - positions[src]
    r = jnp.sqrt(jnp.sum(rvec * rvec, axis=-1) + 1e-12)
    rhat = rvec / r[:, None]
    rb = bessel_basis(r, cfg.n_rbf, cfg.cutoff)
    sh = {l: real_sph_harm(rhat, l) for l in range(cfg.l_max + 1)}
    for lp in params["layers"]:
        rw = jax.nn.silu(rb @ lp["radial_w1"]) @ lp["radial_w2"]
        rw = rw.reshape(-1, len(paths), c)
        msg = {l: 0.0 for l in range(cfg.l_max + 1)}
        for pi, (l1, l2, l3) in enumerate(paths):
            cg = jnp.asarray(gaunt(l1, l2, l3), cfg.jdtype)
            t = jnp.einsum("eca,eb,abm->ecm", h[l1][src], sh[l2], cg)
            msg[l3] = msg[l3] + t * rw[:, pi, :, None]
        for l in range(cfg.l_max + 1):
            agg = jax.ops.segment_sum(msg[l], dst, num_segments=n)
            upd = jnp.einsum("ncm,cd->ndm", agg, lp["mix"][l])
            if l == 0:
                h[l] = h[l] + jax.nn.silu(upd)
            else:
                norm = jnp.sqrt(jnp.sum(upd * upd, axis=-1, keepdims=True)
                                + 1e-12)
                h[l] = h[l] + upd * jax.nn.sigmoid(norm)
    e_atom = (h[0][:, :, 0] @ params["energy_head"])[:, 0]
    return jax.ops.segment_sum(e_atom, graph_ids, num_segments=n_graphs)


def energy_loss(params, species, positions, edges, target, cfg: NequIPConfig):
    e = forward(params, species, positions, edges, cfg)
    return (e - target) ** 2


def batched_energy_loss(params, species, positions, edges, graph_ids,
                        targets, cfg: NequIPConfig):
    e = forward_batched(params, species, positions, edges, graph_ids,
                        targets.shape[0], cfg)
    return jnp.mean((e - targets) ** 2)
