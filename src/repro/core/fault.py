"""Query-engine fault tolerance: failover chains, breakers, deadlines.

The θ bound makes every in-flight top-k query an *anytime* query — the
current TopK heap plus θ is a principled partial answer at any instant —
and every dispatchable op has a bit-identical oracle twin. This module
turns those two facts into a serving-grade degradation story:

- ``run_op``: the failover runner behind every `kernels/ops` dispatch. An
  op call is a chain of (backend, thunk) attempts — kernel → interpret →
  oracle — and on exception, watchdog timeout, or detected corruption the
  next backend runs instead. Backends are bit-identical, so failover never
  changes results.
- ``CircuitBreaker``: per (op, backend) failure memory. N consecutive
  failures open the breaker (the backend is skipped without being tried);
  after a cooldown one half-open probe is allowed, and a success closes it
  again. `BackendPolicy.resolve` consults the breakers (``demote_stage``)
  so *later plans* route around a broken backend at zero per-block cost.
- ``QueryDeadline``: per-query wall-clock (or driver-block) budget. On
  expiry the cursor stops admitting driver blocks and returns the current
  TopK tagged ``partial=True`` with a certified score bound
  (core/executor.QueryCursor).
- ``FaultPlan``: deterministic fault injection at the ops dispatch seam —
  fail op X on call k, delay it past the watchdog, corrupt-then-detect —
  used by tests/test_fault.py to prove bit-identical results under every
  injected failure mode.

The training-loop counterpart is `train/fault.py` (StepGuard /
FailureInjector / run_with_recovery): same philosophy — deadlines, bounded
retries, deterministic injection — applied to the training step instead of
the query block. The serving-layer admission isolation (one tenant's crash
retires only that request) lives in `serve/spatial.py`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib

import numpy as np


class InjectedFault(RuntimeError):
    """Raised at the ops dispatch seam by a matching FaultPlan rule."""


class CorruptionDetected(RuntimeError):
    """An op result failed its structural validator (corrupt-then-detect)."""


class OpTimeout(RuntimeError):
    """A guarded op launch overran the watchdog deadline."""


class FallbackExhausted(RuntimeError):
    """Every backend in an op's failover chain failed (or was skipped by an
    open breaker). The serving layer treats this as transient (the breaker
    half-opens after its cooldown) and retries with backoff."""


# exception types the serving layer retries with backoff; anything else is
# treated as a permanent per-request failure (a real bug, a bad query)
TRANSIENT = (InjectedFault, CorruptionDetected, OpTimeout, FallbackExhausted)


# ---------------------------------------------------------------- deadline --
@dataclasses.dataclass
class QueryDeadline:
    """Per-query execution budget: wall-clock seconds, driver blocks, or
    both. The clock starts at construction (for served requests: at
    submission). ``max_blocks`` is the deterministic form tests use."""
    seconds: float | None = None
    max_blocks: int | None = None
    start: float = dataclasses.field(default_factory=time.monotonic)

    def expired(self, blocks: int = 0) -> bool:
        if self.max_blocks is not None and blocks >= self.max_blocks:
            return True
        return (self.seconds is not None
                and time.monotonic() - self.start >= self.seconds)

    @classmethod
    def after(cls, seconds: float) -> "QueryDeadline":
        return cls(seconds=seconds)


# ---------------------------------------------------------- circuit breaker --
@dataclasses.dataclass
class CircuitBreaker:
    """Per (op, backend) failure memory: closed → open → half-open.

    ``threshold`` consecutive failures open the breaker; while open,
    ``allow()`` is False and the backend is skipped without being tried.
    After ``cooldown_s`` one half-open probe is allowed — a success closes
    the breaker, a failure reopens it (and restarts the cooldown).
    """
    threshold: int = 3
    cooldown_s: float = 30.0
    failures: int = 0
    opened_at: float | None = None
    half_open: bool = False

    @property
    def open(self) -> bool:
        """True until a successful call closes the breaker again."""
        return self.opened_at is not None

    def allow(self) -> bool:
        if self.opened_at is None:
            return True
        if time.monotonic() - self.opened_at < self.cooldown_s:
            return False
        if self.half_open:          # one probe per cooldown window
            return False
        self.half_open = True
        return True

    def ok(self) -> None:
        self.failures = 0
        self.opened_at = None
        self.half_open = False

    def fail(self) -> None:
        self.failures += 1
        if self.half_open or self.failures >= self.threshold:
            self.opened_at = time.monotonic()
            self.half_open = False


# -------------------------------------------------------------- fault plan --
@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One deterministic injection: hit `op` on dispatch call `call`
    (0-based per-op counter; None = every call) with `mode`:

    - ``fail``:    raise InjectedFault before the backend runs
    - ``delay``:   sleep ``delay_s`` inside the guarded launch (pairs with
                   the watchdog to exercise the timeout path)
    - ``corrupt``: poison the backend's result so the op's structural
                   validator rejects it (corrupt-then-detect)

    ``attempts`` is how many chain attempts of the matching call are hit:
    1 (default) fails only the primary backend — the chain recovers
    bit-identically; >= the chain length defeats the whole chain so
    FallbackExhausted surfaces to the serving layer's retry path.
    """
    op: str
    call: int | None = None
    mode: str = "fail"
    delay_s: float = 0.0
    attempts: int = 1


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection, hookable at the ops dispatch seam.

    ``rules`` target specific (op, call) coordinates; ``rate`` adds a
    seeded random primary-attempt failure with probability `rate` per
    dispatch (decided by a stable hash of (seed, op, call index), so the
    draw is independent of op interleaving — the same plan injects the
    same faults whether queries run serially or batched).
    """
    rules: tuple = ()
    rate: float = 0.0
    seed: int = 0
    ops: tuple | None = None          # restrict `rate` to these ops
    calls: dict = dataclasses.field(default_factory=dict)   # op -> count
    injected: int = 0

    def begin_call(self, op: str) -> int:
        idx = self.calls.get(op, 0)
        self.calls[op] = idx + 1
        return idx

    def _rate_hit(self, op: str, call: int) -> bool:
        if self.rate <= 0.0 or (self.ops is not None and op not in self.ops):
            return False
        h = zlib.crc32(f"{self.seed}:{op}:{call}".encode())
        return (h / 0xFFFFFFFF) < self.rate

    def action(self, op: str, call: int, attempt: int) -> tuple | None:
        """Injection for attempt `attempt` of dispatch call `call` of `op`:
        None, ("fail",), ("delay", s) or ("corrupt",)."""
        for r in self.rules:
            if r.op == op and (r.call is None or r.call == call) \
                    and attempt < r.attempts:
                self.injected += 1
                return (r.mode, r.delay_s) if r.mode == "delay" else (r.mode,)
        if attempt == 0 and self._rate_hit(op, call):
            self.injected += 1
            return ("fail",)
        return None


# ------------------------------------------------------------ global state --
@dataclasses.dataclass
class FaultStats:
    failures: int = 0             # backend attempts that raised
    timeouts: int = 0             # ... of which watchdog overruns
    corruptions_detected: int = 0  # validator rejections
    fallbacks: int = 0            # successful non-primary attempts
    exhausted: int = 0            # chains with no surviving backend
    breaker_opens: int = 0
    policy_demotions: int = 0     # plan-time reroutes around open breakers


class FaultState:
    """Process-global failover state: the installed FaultPlan, the
    per-(op, backend) breakers, and the watchdog deadline. Single-writer
    (the query path is single-threaded); watchdog threads never touch it.
    """

    def __init__(self):
        self.plan: FaultPlan | None = None
        self.watchdog_s: float | None = None
        self.breakers: dict[tuple, CircuitBreaker] = {}
        self.breaker_threshold = 3
        self.breaker_cooldown_s = 30.0
        self.stats = FaultStats()

    def breaker(self, op: str, backend: str) -> CircuitBreaker:
        key = (op, backend)
        br = self.breakers.get(key)
        if br is None:
            br = self.breakers[key] = CircuitBreaker(
                threshold=self.breaker_threshold,
                cooldown_s=self.breaker_cooldown_s)
        return br

    def reset(self) -> None:
        self.plan = None
        self.watchdog_s = None
        self.breakers.clear()
        self.stats = FaultStats()


STATE = FaultState()


def install_plan(plan: FaultPlan | None) -> None:
    STATE.plan = plan


@contextlib.contextmanager
def fault_plan(plan: FaultPlan):
    """Install `plan` for the duration of the block (tests)."""
    prev = STATE.plan
    STATE.plan = plan
    try:
        yield plan
    finally:
        STATE.plan = prev


@contextlib.contextmanager
def watchdog(seconds: float | None):
    """Arm the per-launch watchdog for the duration of the block. With no
    watchdog armed (the default) launches run inline at zero overhead."""
    prev = STATE.watchdog_s
    STATE.watchdog_s = seconds
    try:
        yield
    finally:
        STATE.watchdog_s = prev


# ------------------------------------------------------------ failover run --
def _guarded(thunk, watchdog_s: float | None, op: str, backend: str):
    """Run `thunk` under the watchdog. A launch that overruns raises
    OpTimeout and is abandoned (the worker is a daemon thread: a truly hung
    backend no longer stalls the serving loop; a merely-slow one finishes
    into the void — results are discarded, the fallback's are used)."""
    if watchdog_s is None:
        return thunk()
    box: dict = {}

    def work():
        try:
            box["out"] = thunk()
        except Exception as e:      # noqa: BLE001 — relayed below
            box["err"] = e

    t = threading.Thread(target=work, daemon=True,
                         name=f"op-watchdog-{op}-{backend}")
    t.start()
    t.join(watchdog_s)
    if t.is_alive():
        raise OpTimeout(f"{op}/{backend} exceeded {watchdog_s}s watchdog")
    if "err" in box:
        raise box["err"]
    return box["out"]


def _corrupt(out):
    """Poison a result so a structural validator can detect it: the first
    array of the result gets an out-of-domain element 0 (NaN for floats,
    int-min for ints). Only FaultPlan `corrupt` rules call this, and only
    ops with validators should be targeted."""
    arrs = out if isinstance(out, tuple) else (out,)
    first = np.array(np.asarray(arrs[0]))
    flat = first.reshape(-1)
    if len(flat):
        flat[0] = (np.nan if np.issubdtype(first.dtype, np.floating)
                   else np.iinfo(first.dtype).min)
    poisoned = (first,) + tuple(arrs[1:])
    return poisoned if isinstance(out, tuple) else poisoned[0]


def run_op(op: str, attempts: list, validate=None):
    """Run an op through its failover chain.

    `attempts` is the ordered chain [(backend_name, thunk), ...] — every
    backend bit-identical, the last one the always-available oracle. Each
    attempt runs under the watchdog (when armed) and the installed
    FaultPlan's injections; on exception / timeout / validation failure the
    per-(op, backend) breaker records the failure and the next backend
    runs. `validate` is the op's cheap structural check (the
    corrupt-then-detect hook); it runs only under an installed plan so the
    fault-free hot path never pays for it.

    Raises FallbackExhausted when no backend survives.
    """
    st = STATE
    plan = st.plan
    call_idx = plan.begin_call(op) if plan is not None else 0
    last_err = None
    for ai, (backend, thunk) in enumerate(attempts):
        br = st.breakers.get((op, backend)) if st.breakers else None
        if br is not None and not br.allow():
            continue
        try:
            act = plan.action(op, call_idx, ai) if plan is not None else None
            if act is not None and act[0] == "fail":
                raise InjectedFault(
                    f"injected failure: {op}[{call_idx}]/{backend}")
            if act is not None and act[0] == "delay":
                delay = act[1]

                def run(thunk=thunk, delay=delay):
                    time.sleep(delay)
                    return thunk()
            else:
                run = thunk
            out = _guarded(run, st.watchdog_s, op, backend)
            if act is not None and act[0] == "corrupt":
                out = _corrupt(out)
            if validate is not None and plan is not None \
                    and not validate(out):
                st.stats.corruptions_detected += 1
                raise CorruptionDetected(
                    f"{op}/{backend} result failed validation")
            if br is not None:
                br.ok()
            if ai:
                st.stats.fallbacks += 1
            return out
        except Exception as e:      # noqa: BLE001 — any failure fails over
            was_open = st.breaker(op, backend).open
            st.breaker(op, backend).fail()
            if not was_open and st.breaker(op, backend).open:
                st.stats.breaker_opens += 1
            st.stats.failures += 1
            if isinstance(e, OpTimeout):
                st.stats.timeouts += 1
            last_err = e
    st.stats.exhausted += 1
    raise FallbackExhausted(f"every backend failed for {op}") from last_err


# ------------------------------------------------------- policy demotion ----
# Non-oracle backend names per failover-chained op. A breaker open on one of
# these marks the op degraded; breakers on the last-resort oracle/numpy
# fallbacks never demote (there is nothing safer to route to).
_FRAGILE = {"kernel", "interpret", "cpu", "jit", "fused"}

# stage -> {policy backend: (op whose breaker gates it, safe fallback)}
_STAGE_DEMOTIONS = {
    "join": {"fused": ("fused_topk_join", "numpy"),
             "kernel": ("distance_join_matrix", "numpy")},
    "rank": {"kernel": ("merge_join_ranks", "numpy"),
             "interpret": ("merge_join_ranks", "numpy"),
             "cpu": ("merge_join_ranks", "numpy")},
    "probe": {"kernel": ("bloom_probe", "numpy"),
              "interpret": ("bloom_probe", "numpy")},
    "descend": {"kernel": ("tree_descend", "numpy"),
                "interpret": ("tree_descend", "numpy")},
}


def op_degraded(op: str) -> bool:
    """Is any non-oracle backend of `op` currently breaker-open?"""
    return any(o == op and b in _FRAGILE and br.open
               for (o, b), br in STATE.breakers.items())


def demote_stage(stage: str, backend: str) -> str:
    """Plan-time reroute: if the op behind a stage's resolved backend is
    breaker-open, resolve to the safe fallback instead — later plans skip
    the broken backend entirely (zero per-block cost). Called from
    `BackendPolicy.resolve`; a clean breaker registry is a no-op."""
    if not STATE.breakers:
        return backend
    ent = _STAGE_DEMOTIONS.get(stage, {}).get(backend)
    if ent is not None and op_degraded(ent[0]):
        STATE.stats.policy_demotions += 1
        return ent[1]
    return backend
