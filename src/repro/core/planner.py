"""Query planning: driver/driven split, join ordering, plan skeletons (§3.3.2).

The driver sub-query gets the Quark-X / SPARQL-RANK heuristic: its primary
numeric (ranking) predicate is pushed to the *deepest* position, i.e. the
driver is enumerated in score-key order through the sorted numeric index, so
blocks arrive best-first and the top-k threshold can terminate the scan.
Remaining driver patterns are joined greedily smallest-cardinality-first
(Selinger-style cost heuristic on index-scan cardinalities).

The driven side keeps BOTH skeletons (N-Plan / S-Plan); APS routes each block.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .policy import BackendPolicy
from .query import Query, SpatialFilter, TriplePattern, Var
from .store import DirectedNumericScan, QuadStore


@dataclasses.dataclass
class SidePlan:
    entity_var: str                      # variable bound to the spatial entity
    patterns: list                       # all patterns of this side
    join_patterns: list                  # block-join chain (excl. primary)
    all_ordered: list                    # full chain incl. primary (S-Plan)
    quant_terms: list                    # [(pattern, obj_var, weight), ...]
    primary: tuple | None                # (pattern, obj_var, weight) driving scan
    scan: DirectedNumericScan | None     # primary numeric scan (score order)

    def weight_of(self, var_name: str) -> float:
        for _, v, w in self.quant_terms:
            if v == var_name:
                return w
        return 0.0


def _connectivity_order(store: QuadStore, patterns: list,
                        seed_vars: set) -> list:
    """Greedy smallest-cardinality-first join chain where every step shares a
    variable with what has been joined so far (avoids cartesian products)."""
    remaining = list(patterns)
    reached = set(seed_vars)
    ordered: list = []
    cards = {id(tp): _estimate_card(store, tp) for tp in remaining}
    while remaining:
        connected = [tp for tp in remaining
                     if {v.name for v in tp.vars()} & reached]
        pool = connected if connected else remaining
        best = min(pool, key=lambda tp: cards[id(tp)])
        ordered.append(best)
        reached |= {v.name for v in best.vars()}
        remaining.remove(best)
    return ordered


@dataclasses.dataclass
class QueryPlan:
    driver: SidePlan
    driven: SidePlan
    dist_world: float
    dist_norm: float
    metric: str
    driven_cs: np.ndarray
    descending: bool
    k: int
    # backend selection (core/policy.BackendPolicy), resolved ONCE at plan
    # time so the per-block hot paths — APS plan switches, SIP prefetch,
    # the Phase-3 join — read plain strings with zero dispatch cost
    join_impl: str = "merge"            # relational primitive (JOIN_IMPLS)
    rank_backend: str | None = None     # merge-join rank pass (RANK_BACKENDS)
    probe_backend: str | None = None    # Bloom CS probes (PROBE_BACKENDS)
    join_backend: str = "numpy"         # Phase-3 MBR join (JOIN_BACKENDS)
    descend_backend: str = "numpy"      # Phase-1 traversal (DESCEND_BACKENDS)
    shape: str = "topk"                 # query shape (core/query.Query.shape)


def resolve_spatial_vars(store: QuadStore, q: Query) -> tuple[str, str]:
    """Map FILTER(distance(?ga, ?gb)) geometry vars to their subject entity
    vars when they are objects of a hasGeometry pattern."""
    def resolve(v: Var) -> str:
        for tp in q.patterns:
            if (isinstance(tp.o, Var) and tp.o.name == v.name
                    and tp.p == store.geometry_predicate
                    and isinstance(tp.s, Var)):
                return tp.s.name
        return v.name
    var_a = resolve(q.spatial.a)
    # unary shapes (range / within-distance) have no second geometry var;
    # the "driven" side collapses to the driver's entity var (empty side)
    var_b = resolve(q.spatial.b) if q.spatial.b is not None else var_a
    return var_a, var_b


def _connected_component(patterns: list, seed_var: str) -> list:
    """Patterns reachable from seed_var through shared variables."""
    reach = {seed_var}
    chosen: list = []
    remaining = list(patterns)
    changed = True
    while changed:
        changed = False
        for tp in list(remaining):
            names = {v.name for v in tp.vars()}
            if names & reach:
                reach |= names
                chosen.append(tp)
                remaining.remove(tp)
                changed = True
    return chosen


def _estimate_card(store: QuadStore, tp: TriplePattern) -> int:
    """Cheap cardinality estimate: exact count via index range scan."""
    return len(_scan_rows(store, tp))


def _scan_rows(store, tp):
    def const(t):
        return None if (t is None or isinstance(t, Var)) else int(t)
    return store.scan(g=const(tp.g), s=const(tp.s), p=const(tp.p), o=const(tp.o))


def _build_side(store: QuadStore, patterns: list, entity_var: str,
                ranking_weights: dict, descending: bool) -> SidePlan:
    quant_terms = []
    for tp in patterns:
        if isinstance(tp.o, Var) and tp.o.name in ranking_weights \
                and not isinstance(tp.p, Var) and int(tp.p) in store.numeric:
            quant_terms.append((tp, tp.o.name, ranking_weights[tp.o.name]))
    primary = None
    scan = None
    if quant_terms:
        # primary = the largest-|weight| quantifiable TP (ties: largest index,
        # which maximizes the benefit of the sorted scan)
        primary = max(quant_terms,
                      key=lambda t: (abs(t[2]), store.numeric[int(t[0].p)].n_rows))
        scan = DirectedNumericScan(store.numeric[int(primary[0].p)], descending)
    # drop the hasGeometry pattern from the join chains: it is implied by the
    # spatial id (S bit) and the tree holds the geometry
    joinable = [tp for tp in patterns if tp.p != store.geometry_predicate]
    seed = {entity_var}
    if primary is not None:
        seed |= {v.name for v in primary[0].vars()}
    rest = [tp for tp in joinable if primary is None or tp is not primary[0]]
    rest = _connectivity_order(store, rest, seed)
    all_ordered = _connectivity_order(store, joinable, {entity_var})
    return SidePlan(entity_var=entity_var, patterns=patterns,
                    join_patterns=rest, all_ordered=all_ordered,
                    quant_terms=quant_terms, primary=primary, scan=scan)


def plan_query(store: QuadStore, q: Query,
               force_driver: str | None = None,
               join_impl: str | None = None,
               rank_backend: str | None = None,
               policy: BackendPolicy | None = None) -> QueryPlan:
    """Plan a spatial top-k query.

    `policy` fixes every stage backend (core/policy.BackendPolicy; resolved
    here if it still carries "auto" entries). The `join_impl` /
    `rank_backend` kwargs are the pre-policy per-stage form, kept for
    direct callers; they are ignored when `policy` is given.
    """
    assert q.spatial is not None, "plan_query expects a spatial query"
    shape = q.shape()
    if shape in ("range", "within", "knn", "join") and q.ranking is not None:
        raise ValueError(
            f"{shape!r}-shaped queries are selections; ranking is only "
            "supported on the top-k distance-join shape")
    if shape in ("range", "within") and q.spatial.b is not None:
        raise ValueError(f"{shape!r}-shaped queries are unary: spatial.b "
                         "must be None")
    if shape in ("knn", "join", "topk") and q.spatial.b is None:
        raise ValueError(f"{shape!r}-shaped queries need spatial.b")
    if policy is None:
        policy = BackendPolicy(impl=join_impl or "auto",
                               rank=rank_backend or "auto")
    policy = policy.resolve()
    var_a, var_b = resolve_spatial_vars(store, q)
    patterns = list(q.patterns)
    side_a_patterns = _connected_component(patterns, var_a)
    covered = set(map(id, side_a_patterns))
    side_b_patterns = [tp for tp in patterns if id(tp) not in covered]
    # safety: anything left unattached joins the a-side
    if shape in ("range", "within") and side_b_patterns:
        # unary shapes have one side only; disconnected patterns would
        # otherwise dangle on a nonexistent driven side
        side_a_patterns = side_a_patterns + side_b_patterns
        side_b_patterns = []
    ranking_weights = {v.name: w for v, w in (q.ranking.terms if q.ranking else ())}
    descending = q.ranking.descending if q.ranking else True

    side_a = _build_side(store, side_a_patterns, var_a, ranking_weights, descending)
    side_b = _build_side(store, side_b_patterns, var_b, ranking_weights, descending)

    # driver choice (paper: APS picks driver/driven): prefer the side with a
    # primary numeric scan; among those, the smaller index converges faster.
    def scan_rows(sp: SidePlan) -> int:
        return sp.scan.n_rows if sp.scan is not None else 1 << 62
    if shape in ("range", "within", "knn"):
        # unary shapes have only the a-side; kNN's FILTER is directional
        # (k nearest ?b per ?a entity), so ?a's side MUST drive
        driver, driven = side_a, side_b
    elif force_driver == "a":
        driver, driven = side_a, side_b
    elif force_driver == "b":
        driver, driven = side_b, side_a
    elif (side_a.scan is None) != (side_b.scan is None):
        driver, driven = (side_a, side_b) if side_a.scan else (side_b, side_a)
    else:
        driver, driven = ((side_a, side_b)
                          if scan_rows(side_a) <= scan_rows(side_b)
                          else (side_b, side_a))

    # driven CS compatibility: every CS whose predicate set contains the
    # driven entity's query predicates. Unary shapes filter the DRIVER's
    # entities against the tree (there is no driven side), so their CS set
    # comes from the driver's patterns instead.
    cs_side = driver if shape in ("range", "within") else driven
    driven_preds = {int(tp.p) for tp in cs_side.patterns
                    if isinstance(tp.s, Var) and tp.s.name == cs_side.entity_var
                    and not isinstance(tp.p, Var)}
    matching = [cid for cid, preds in store.cs_catalog.items()
                if driven_preds <= preds]
    driven_cs = np.array(sorted(matching), dtype=np.int64)

    dist_norm = store.tree.extent.denormalize_distance(q.spatial.dist)
    return QueryPlan(driver=driver, driven=driven,
                     dist_world=q.spatial.dist, dist_norm=dist_norm,
                     metric=q.spatial.metric, driven_cs=driven_cs,
                     descending=descending, k=q.k,
                     join_impl=policy.impl, rank_backend=policy.rank,
                     probe_backend=policy.probe, join_backend=policy.join,
                     descend_backend=policy.descend, shape=shape)
