"""Characteristic sets (soft schema) and Bloom filters (paper §3.1.3).

A characteristic set (CS) of an entity is the set of predicates attached to it
[Neumann & Moerkotte '11]. STREAK stores, per S-QuadTree node, Bloom filters
over the CS ids of (a) the spatial objects intersecting the node ("self"),
(b) entities with edges *into* those objects ("incoming"), and (c) entities
reached by edges *out of* them ("outgoing") — enabling the focused traversal
of Phase 1 and the cardinality statistics of the cost model.

Bloom filters are bit-packed uint32 words; probes are pure integer math so the
query path can run them vectorized (or through the `bloom_probe` Pallas
kernel).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# 64-bit splitmix-style avalanche; good enough + trivially portable to jnp.
_C1 = np.uint64(0xBF58476D1CE4E5B9)
_C2 = np.uint64(0x94D049BB133111EB)


def _mix(x: np.ndarray, seed: int) -> np.ndarray:
    with np.errstate(over="ignore"):
        x = np.asarray(x).astype(np.uint64) \
            + np.uint64(0x9E3779B97F4A7C15) * np.uint64(seed + 1)
        x ^= x >> np.uint64(30)
        x = x * _C1
        x ^= x >> np.uint64(27)
        x = x * _C2
        x ^= x >> np.uint64(31)
    return x


def hash_u64(x: np.ndarray, seed: int = 0) -> np.ndarray:
    return _mix(np.asarray(x, dtype=np.int64).view(np.uint64), seed)


# 32-bit murmur3-finalizer family. Bloom probes use THIS family so that the
# numpy path, the jnp reference, and the Pallas `bloom_probe` kernel (which
# runs 32-bit math on TPU) produce identical bit positions.
def mix32(x: np.ndarray, seed: int) -> np.ndarray:
    with np.errstate(over="ignore"):
        x = np.asarray(x, dtype=np.uint32) \
            + np.uint32(0x9E3779B9) * np.uint32(seed + 1)
        x ^= x >> np.uint32(16)
        x = x * np.uint32(0x85EBCA6B)
        x ^= x >> np.uint32(13)
        x = x * np.uint32(0xC2B2AE35)
        x ^= x >> np.uint32(16)
    return x


def hash32(keys: np.ndarray, seed: int = 0) -> np.ndarray:
    """uint32 hash of int64 keys = mix32(lo32 ^ mix32(hi32))."""
    u = np.asarray(keys, dtype=np.int64).view(np.uint64)
    lo = (u & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (u >> np.uint64(32)).astype(np.uint32)
    return mix32(lo ^ mix32(hi, seed + 7), seed)


def cs_id_of_predicate_sets(pred_lists: list[np.ndarray]) -> np.ndarray:
    """Map each entity's sorted predicate set to a stable 63-bit CS id."""
    out = np.empty(len(pred_lists), dtype=np.int64)
    for i, preds in enumerate(pred_lists):
        preds = np.unique(np.asarray(preds, dtype=np.int64))
        h = np.uint64(0x243F6A8885A308D3)
        for p in preds:
            h = _mix(np.uint64(h) ^ np.uint64(p), 17)
        out[i] = np.int64(h & np.uint64(0x7FFFFFFFFFFFFFFF))
    return out


def _cs_ids_segmented(p: np.ndarray, starts: np.ndarray,
                      ends: np.ndarray) -> np.ndarray:
    """CS ids for segments of a (within-segment sorted) predicate column.

    Bit-identical to `cs_id_of_predicate_sets` applied per segment, but
    vectorized ACROSS segments: the hash chain is sequential in the j-th
    distinct predicate, so the loop runs over j (max distinct preds per
    subject — single digits) instead of over subjects.
    """
    n_seg = len(starts)
    out = np.full(n_seg, np.uint64(0x243F6A8885A308D3))
    if len(p) == 0 or n_seg == 0:
        return (out & np.uint64(0x7FFFFFFFFFFFFFFF)).astype(np.int64)
    p = np.asarray(p, dtype=np.int64)
    # within-segment dedup (p is sorted inside each segment; a boundary
    # repeating the previous segment's last value must survive)
    keep = np.ones(len(p), dtype=bool)
    keep[1:] = p[1:] != p[:-1]
    keep[starts] = True
    idx = np.flatnonzero(keep)
    seg = np.searchsorted(starts, idx, side="right") - 1
    cnt = np.bincount(seg, minlength=n_seg)
    first = np.concatenate([[0], np.cumsum(cnt)[:-1]])
    for j in range(int(cnt.max(initial=0))):
        sel = cnt > j
        pj = p[idx[first[sel] + j]].astype(np.uint64)
        out[sel] = _mix(out[sel] ^ pj, 17)
    return (out & np.uint64(0x7FFFFFFFFFFFFFFF)).astype(np.int64)


def compute_characteristic_sets(subjects: np.ndarray, predicates: np.ndarray
                                ) -> tuple[np.ndarray, np.ndarray]:
    """Per-distinct-subject CS ids from (subject, predicate) columns.

    Returns (distinct_subjects_sorted, cs_ids aligned to them).
    """
    order = np.lexsort((predicates, subjects))
    s, p = subjects[order], predicates[order]
    uniq, starts = np.unique(s, return_index=True)
    ends = np.append(starts[1:], len(s))
    return uniq, _cs_ids_segmented(p, starts, ends)


def cs_catalog(subjects: np.ndarray, predicates: np.ndarray) -> dict:
    """cs_id -> frozenset(predicate ids). Used at query time to find every CS
    compatible with the driven sub-query's predicate set (query preds must be
    a subset of the CS)."""
    order = np.lexsort((predicates, subjects))
    s, p = subjects[order], predicates[order]
    uniq, starts = np.unique(s, return_index=True)
    ends = np.append(starts[1:], len(s))
    cs = _cs_ids_segmented(p, starts, ends)
    catalog: dict = {}
    # one frozenset per DISTINCT CS id (subjects sharing a CS share it)
    _, firsts = np.unique(cs, return_index=True)
    for i in firsts:
        a, b = starts[i], ends[i]
        catalog[int(cs[i])] = frozenset(int(x) for x in np.unique(p[a:b]))
    return catalog


@dataclasses.dataclass(frozen=True)
class PreparedKeys:
    """Hoisted Bloom-probe material for a fixed key set.

    Phase 1 probes the same driven-CS keys against every frontier node of
    every driver block, so the double-hashing positions (and the 32-bit key
    halves the Pallas kernel consumes) are query-invariant — the executor
    prepares them once per query and the level-synchronous frontier reuses
    them for every level of every lookahead window.
    """

    keys: np.ndarray    # (C,) int64 original keys
    word: np.ndarray    # (C, k) int64 word index per probe
    shift: np.ndarray   # (C, k) uint32 bit offset per probe
    nbits: int          # filter geometry the positions were computed for
    k: int

    def __len__(self) -> int:
        return len(self.keys)


# Probe-backend dispatch for the query path. "numpy" is the oracle;
# "kernel" routes through kernels/ops.bloom_probe (native Pallas on TPU, the
# jnp reference on CPU); "interpret" forces the Pallas kernel in interpret
# mode (tests). "auto" resolves to the kernel only when a TPU is attached —
# per-level frontier shapes vary, so on CPU the numpy path stays fastest.
PROBE_BACKENDS = ("auto", "numpy", "kernel", "interpret")
_auto_backend: str | None = None


def resolve_probe_backend(backend: str | None) -> str:
    global _auto_backend
    b = backend or "auto"
    if b not in PROBE_BACKENDS:
        raise ValueError(f"unknown probe backend {b!r}")
    if b != "auto":
        return b
    if _auto_backend is None:
        try:
            import jax
            _auto_backend = ("kernel" if jax.default_backend() == "tpu"
                             else "numpy")
        except Exception:  # pragma: no cover - jax is a hard dep in practice
            _auto_backend = "numpy"
    return _auto_backend


@dataclasses.dataclass
class BloomBank:
    """`n_filters` Bloom filters of `words * 32` bits each, k hash probes."""

    bits: np.ndarray  # (n_filters, words) uint32
    k: int = 3

    @staticmethod
    def empty(n_filters: int, words: int = 8, k: int = 3) -> "BloomBank":
        return BloomBank(np.zeros((n_filters, words), dtype=np.uint32), k)

    @property
    def words(self) -> int:
        return self.bits.shape[1]

    @property
    def nbits(self) -> int:
        return self.words * 32

    def _positions(self, keys: np.ndarray) -> np.ndarray:
        """(len(keys), k) bit positions via double hashing h1 + i*h2."""
        keys = np.asarray(keys, dtype=np.int64)
        h1 = hash32(keys, 0)
        h2 = hash32(keys, 1) | np.uint32(1)
        i = np.arange(self.k, dtype=np.uint32)
        with np.errstate(over="ignore"):
            pos = (h1[:, None] + i[None, :] * h2[:, None]) \
                % np.uint32(self.nbits)
        return pos.astype(np.int64)

    def add(self, filter_idx: np.ndarray, keys: np.ndarray) -> None:
        """Insert keys[i] into filter filter_idx[i] (vectorized)."""
        pos = self._positions(keys)                      # (n, k)
        w, b = pos // 32, (pos % 32).astype(np.uint32)
        fi = np.broadcast_to(np.asarray(filter_idx)[:, None], pos.shape)
        np.bitwise_or.at(self.bits, (fi.ravel(), w.ravel()),
                         (np.uint32(1) << b.ravel()))

    def contains(self, filter_idx: np.ndarray, keys: np.ndarray) -> np.ndarray:
        """Probe keys[i] against filter filter_idx[i]; broadcast-compatible."""
        pos = self._positions(keys)
        w, b = pos // 32, (pos % 32).astype(np.uint32)
        fi = np.broadcast_to(np.asarray(filter_idx)[:, None], pos.shape)
        word = self.bits[fi, w]
        return ((word >> b) & np.uint32(1)).all(axis=-1)

    def contains_any(self, filter_idx: int, keys: np.ndarray) -> bool:
        """Does filter contain ANY of `keys`? (used for driven-CS checks)."""
        fi = np.full(len(keys), filter_idx, dtype=np.int64)
        return bool(self.contains(fi, keys).any())

    def prepare(self, keys: np.ndarray) -> PreparedKeys:
        """Hoist the double-hashing of `keys` into a reusable PreparedKeys."""
        keys = np.asarray(keys, dtype=np.int64)
        pos = self._positions(keys)                      # (C, k)
        return PreparedKeys(keys=keys, word=pos // 32,
                            shift=(pos % 32).astype(np.uint32),
                            nbits=self.nbits, k=self.k)

    def contains_prepared(self, filter_idx: np.ndarray,
                          prep: PreparedKeys) -> np.ndarray:
        """(len(filter_idx), len(prep)) bool probe matrix, hashing hoisted."""
        assert prep.nbits == self.nbits and prep.k == self.k
        fi = np.asarray(filter_idx, dtype=np.int64)
        word = self.bits[fi[:, None, None], prep.word[None]]   # (F, C, k)
        return ((word >> prep.shift[None]) & np.uint32(1)).all(axis=-1)

    def contains_any_batch(self, filter_idx: np.ndarray, prep: PreparedKeys,
                           backend: str | None = None) -> np.ndarray:
        """Per-filter ANY over a prepared key set -> (len(filter_idx),) bool.

        This is the Phase-1 frontier probe: `backend` picks the numpy oracle
        or the Pallas `bloom_probe` kernel route (see PROBE_BACKENDS). All
        routes run the same 32-bit integer math, so results are bit-identical.
        """
        fi = np.asarray(filter_idx, dtype=np.int64)
        if len(fi) == 0 or len(prep) == 0:
            return np.zeros(len(fi), dtype=bool)
        backend = resolve_probe_backend(backend)
        if backend == "numpy":
            return self.contains_prepared(fi, prep).any(axis=-1)
        from ..kernels import ops  # lazy: keep charsets importable without jax
        rows = self.bits[np.repeat(fi, len(prep))]       # (F*C, W)
        keys = np.tile(prep.keys, len(fi))               # (F*C,)
        hit = ops.bloom_probe(rows, keys, k=self.k,
                              interpret=backend == "interpret")
        return np.asarray(hit).reshape(len(fi), len(prep)).any(axis=-1)

    def nbytes(self) -> int:
        return self.bits.nbytes


@dataclasses.dataclass
class NodeCSStats:
    """Per-node CS cardinalities in CSR form (node -> [(cs_id, count)])."""

    offsets: np.ndarray   # (n_nodes + 1,) int64
    cs_ids: np.ndarray    # (nnz,) int64, sorted within each node
    counts: np.ndarray    # (nnz,) int64

    def cardinality_all(self, cs_query: np.ndarray) -> np.ndarray:
        """Vectorized per-node total count of objects whose CS is in
        `cs_query` -> (n_nodes,). One pass over the CSR; query-invariant
        across driver blocks, so the executor computes it once per query."""
        n_nodes = len(self.offsets) - 1
        if len(self.cs_ids) == 0 or len(cs_query) == 0:
            return np.zeros(n_nodes, dtype=np.int64)
        hit = np.isin(self.cs_ids, np.asarray(cs_query, dtype=np.int64))
        contrib = np.where(hit, self.counts, 0)
        csum = np.concatenate([[0], np.cumsum(contrib)])
        return csum[self.offsets[1:]] - csum[self.offsets[:-1]]

    def cardinality(self, node: int, cs_query: np.ndarray) -> int:
        """Total count of objects at `node` whose CS is in `cs_query`.

        This is C(R) of the paper's cost model when `cs_query` is the driven
        sub-query's CS set, and |CS(a)| in cost(a).
        """
        a, b = self.offsets[node], self.offsets[node + 1]
        ids, cnt = self.cs_ids[a:b], self.counts[a:b]
        idx = np.searchsorted(ids, np.asarray(cs_query, dtype=np.int64))
        idx = np.clip(idx, 0, len(ids) - 1) if len(ids) else idx
        if len(ids) == 0:
            return 0
        hit = ids[idx] == np.asarray(cs_query, dtype=np.int64)
        return int(cnt[idx][hit].sum())

    def nbytes(self) -> int:
        return self.offsets.nbytes + self.cs_ids.nbytes + self.counts.nbytes


def build_node_cs_stats(node_of_item: np.ndarray, cs_of_item: np.ndarray,
                        n_nodes: int) -> NodeCSStats:
    """Aggregate (node, cs) -> count into CSR. Items may repeat nodes."""
    if len(node_of_item) == 0:
        return NodeCSStats(np.zeros(n_nodes + 1, dtype=np.int64),
                           np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))
    order = np.lexsort((cs_of_item, node_of_item))
    n, c = node_of_item[order], cs_of_item[order]
    key_change = np.empty(len(n), dtype=bool)
    key_change[0] = True
    key_change[1:] = (n[1:] != n[:-1]) | (c[1:] != c[:-1])
    group = np.cumsum(key_change) - 1
    counts = np.bincount(group)
    firsts = np.flatnonzero(key_change)
    gn, gc = n[firsts], c[firsts]
    offsets = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(offsets, gn + 1, 1)
    offsets = np.cumsum(offsets)
    return NodeCSStats(offsets, gc.astype(np.int64), counts.astype(np.int64))
