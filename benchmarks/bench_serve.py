"""Multi-tenant serving: 8 concurrent lgd queries through the slot-based
admission loop vs serial per-query execution.

Two request mixes bracket the serving layer's win:

- ``hotq``: 8 tenants all running the hot lgd query shape with per-tenant
  ``k`` — the classic serving workload (many users, one popular query).
  Cross-tenant sharing (driver-block materialization, S/N-Plan retrieval,
  pooled+deduped SIP rows, MBR pairs, refine verdicts — all θ-independent,
  hence bit-exact) collapses the redundant per-tenant work; this is the
  headline ≥2x row.
- ``mixed``: the 8 distinct lgd query shapes with mixed ``k`` — no
  cross-tenant redundancy to harvest, so this isolates the pure
  batching/scheduling overhead of the serve loop (must stay ~parity).

And two serial baselines per mix:

- ``serial_perquery``: a fresh StreakEngine per query — the deployment
  without a serving layer (per-request engine instantiation, no shared
  caches, no cross-query batching). This is the headline comparison.
- ``serial_warm``: one shared engine executing the batch back-to-back with
  hot caches — the upper bound a perfectly warmed sequential executor can
  reach without the serving layer's cross-tenant sharing.

Every run asserts per-query results are bit-identical to serial execution.

A third ``faulted`` row (fused config) re-runs the serve batch under a
seeded 1% fault-injection plan at the kernel dispatch seam: the failover
chains must absorb every injected failure with zero result drift, and the
``throughput_ratio_vs_fault_free`` derived metric tracks the recovery
overhead (the acceptance floor is 0.8).

The ``openloop`` section drives the same serve loop open-loop: requests
arrive on a fixed virtual-time schedule at an offered load set as a
fraction of the measured closed-loop capacity (0.5x / 0.8x / 1.2x),
independent of completions, so queueing delay is part of the measured
latency. Per load it reports mean and p50/p95/p99 latency — the 1.2x row
shows the queue growing (p99 >> p50), the 0.5x row the uncongested floor.

Standalone: ``python -m benchmarks.bench_serve --json`` writes
``BENCH_serve.json`` (the artifact CI uploads).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import BackendPolicy, ExecConfig, StreakEngine
from repro.core import fault
from repro.serve.spatial import SpatialRequest, SpatialServeEngine

from . import common

N_CONCURRENT = 8
MAX_SLOTS = 8
KS = (5, 10, 20, 40, 60, 80, 100, 120)   # per-tenant k mix

CONFIGS = {
    "numpy": ExecConfig(),
    "fused": ExecConfig(policy=BackendPolicy(join="fused", kcap="auto")),
}


def _mixes(ds) -> dict:
    return {
        "hotq": [dataclasses.replace(ds.queries[0], k=k) for k in KS],
        "mixed": [dataclasses.replace(q, k=k)
                  for q, k in zip(ds.queries, KS)],
    }


def _assert_identical(reqs, serial) -> None:
    for req, (scores, rows, _) in zip(reqs, serial):
        assert req.done
        np.testing.assert_array_equal(req.scores, scores)
        assert req.rows.n == rows.n


def run() -> list:
    ds = common.dataset("lgd")
    rows = []
    for mname, queries in _mixes(ds).items():
        for cname, cfg in CONFIGS.items():
            # ---- serial baselines ---------------------------------------
            def serial_perquery():
                return [StreakEngine(ds.store, cfg).execute(q)
                        for q in queries]

            serial = serial_perquery()                   # also warms jit
            t_cold = common.timeit(serial_perquery, warmup=0, repeat=3)
            warm_eng = StreakEngine(ds.store, cfg)
            t_warm = common.timeit(
                lambda: [warm_eng.execute(q) for q in queries])

            # ---- serving loop (fresh serve engine per repeat: a batch of
            # 8 arriving tenants, caches shared only within the batch) -----
            def serve_batch():
                srv = SpatialServeEngine(ds.store, cfg, max_slots=MAX_SLOTS)
                return srv, srv.serve(queries)

            srv, reqs = serve_batch()             # warm + correctness check
            _assert_identical(reqs, serial)
            assert srv.stats.slot_reuse >= 0 and srv.stats.sip_batches > 0
            t_srv = common.timeit(lambda: serve_batch()[1])

            qps = N_CONCURRENT / (t_srv / 1e6)
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_batched_{N_CONCURRENT}q", t_srv,
                f"speedup_vs_serial_perquery={t_cold / max(t_srv, 1):.2f}x"
                f";speedup_vs_serial_warm={t_warm / max(t_srv, 1):.2f}x"
                f";qps={qps:.1f};bit_identical=true"))
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_serial_perquery"
                f"_{N_CONCURRENT}q", t_cold, ""))
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_serial_warm"
                f"_{N_CONCURRENT}q", t_warm, ""))

            if cname != "fused":
                continue
            # ---- fault-injected serving: seeded 1% failures at the kernel
            # dispatch seam; failover absorbs them bit-identically and the
            # throughput ratio vs the fault-free run tracks the overhead ---
            def serve_faulted():
                fault.STATE.reset()
                # seed picked so the 1% rate actually lands hits in both
                # mixes' dispatch streams (hotq makes only ~65 op calls)
                fault.install_plan(fault.FaultPlan(rate=0.01, seed=8))
                try:
                    srv = SpatialServeEngine(ds.store, cfg,
                                             max_slots=MAX_SLOTS)
                    reqs = srv.serve(queries)
                    return srv, reqs, fault.STATE.plan.injected
                finally:
                    fault.STATE.reset()

            fsrv, freqs, injected = serve_faulted()
            assert injected > 0, "1% plan must actually fire at bench scale"
            assert all(r.error is None for r in freqs)
            _assert_identical(freqs, serial)
            t_fault = common.timeit(lambda: serve_faulted()[1])
            rows.append(common.row(
                f"serve/lgd/{mname}/{cname}_batched_{N_CONCURRENT}q_faulted",
                t_fault,
                f"injected={injected}"
                f";throughput_ratio_vs_fault_free="
                f"{t_srv / max(t_fault, 1):.2f}"
                f";bit_identical=true"))
    rows += openloop(ds)
    return rows


OPENLOOP_N_REQ = 48
OPENLOOP_LOADS = (0.5, 0.8, 1.2)


def openloop(ds) -> list:
    """Open-loop arrival-rate sweep: latency percentiles vs offered load.

    Arrivals advance on a virtual clock fed by the measured wall time of
    each `step()` call — request i arrives at ``i / offered_qps`` whether
    or not the loop has kept up, so above capacity the queue (and the tail
    latency) grows, which a closed-loop batch bench can never show.
    """
    cfg = CONFIGS["fused"]
    queries = _mixes(ds)["mixed"]

    def batch():
        return SpatialServeEngine(ds.store, cfg,
                                  max_slots=MAX_SLOTS).serve(queries)

    batch()                                            # warm jit caches
    t_batch = common.timeit(batch, warmup=0, repeat=3)
    cap_qps = len(queries) / (t_batch / 1e6)
    rows = [common.row("serve/lgd/openloop/capacity", t_batch,
                       f"closed_loop_qps={cap_qps:.1f}")]
    n = OPENLOOP_N_REQ
    for frac in OPENLOOP_LOADS:
        qps = cap_qps * frac
        arrivals = np.arange(n) / qps                  # virtual seconds
        srv = SpatialServeEngine(ds.store, cfg, max_slots=MAX_SLOTS)
        reqs = [SpatialRequest(rid=i, query=queries[i % len(queries)])
                for i in range(n)]
        now, nxt = 0.0, 0
        done_at: dict[int, float] = {}
        while len(done_at) < n:
            while nxt < n and arrivals[nxt] <= now:
                srv.submit(reqs[nxt])
                nxt += 1
            if not any(srv.slots) and not srv.queue:
                now = arrivals[nxt]                    # idle: jump ahead
                continue
            t0 = time.perf_counter()
            srv.step()
            now += time.perf_counter() - t0
            for r in reqs[:nxt]:
                if r.done and r.rid not in done_at:
                    done_at[r.rid] = now
        assert all(r.error is None for r in reqs)
        lat = np.array([done_at[i] - arrivals[i] for i in range(n)]) * 1e6
        p50, p95, p99 = (np.percentile(lat, p) for p in (50, 95, 99))
        rows.append(common.row(
            f"serve/lgd/openloop/load{frac:g}x", float(lat.mean()),
            f"offered_qps={qps:.1f};p50_us={p50:.0f};p95_us={p95:.0f};"
            f"p99_us={p99:.0f};n_req={n};max_queue={srv.stats.max_queue}"))
    return rows


def main() -> None:
    import json
    import sys
    print("name,us_per_call,derived")
    out = []
    for r in run():
        print(r)
        name, us, derived = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    if "--json" in sys.argv[1:]:
        with open("BENCH_serve.json", "w") as fh:
            json.dump(out, fh, indent=1)
        print("# wrote BENCH_serve.json", file=sys.stderr)


if __name__ == "__main__":
    main()
