"""Vectorized relational algebra over column blocks.

A Relation is a dict of equal-length int64 numpy columns keyed by variable
name. Joins are sort-merge over composite keys (numpy lexsort + searchsorted),
which is the vectorized analogue of RDF-3X's merge joins over sorted index
scans.
"""
from __future__ import annotations

import numpy as np

from .query import TriplePattern, Var
from .store import G, O, P, QuadStore, S


class Relation(dict):
    """dict[str, np.ndarray] with aligned rows."""

    @property
    def n(self) -> int:
        for v in self.values():
            return len(v)
        return 0

    def take(self, idx: np.ndarray) -> "Relation":
        return Relation({k: v[idx] for k, v in self.items()})

    def head(self, n: int) -> "Relation":
        return Relation({k: v[:n] for k, v in self.items()})

    @staticmethod
    def empty(cols: list[str]) -> "Relation":
        return Relation({c: np.empty(0, dtype=np.int64) for c in cols})


def scan_pattern(store: QuadStore, tp: TriplePattern) -> Relation:
    """Index scan for one quad pattern -> relation over its variables."""
    def const(t):
        return None if (t is None or isinstance(t, Var)) else int(t)
    rows = store.scan(g=const(tp.g), s=const(tp.s), p=const(tp.p), o=const(tp.o))
    slots = ((tp.g, G), (tp.s, S), (tp.p, P), (tp.o, O))
    var_cols: dict[str, list[int]] = {}
    for term, col in slots:
        if isinstance(term, Var):
            var_cols.setdefault(term.name, []).append(col)
    # repeated variable within one pattern -> intra-row equality filter
    mask = np.ones(len(rows), dtype=bool)
    for cols in var_cols.values():
        for c in cols[1:]:
            mask &= rows[:, cols[0]] == rows[:, c]
    if not mask.all():
        rows = rows[mask]
    return Relation({name: rows[:, cols[0]].copy()
                     for name, cols in var_cols.items()})


def _composite_key(rel: Relation, names: list[str]) -> np.ndarray:
    """Lexicographic rank array for the given columns (stable)."""
    cols = [rel[n] for n in names]
    order = np.lexsort(tuple(reversed(cols)))
    return order


def join(a: Relation, b: Relation, on: list[str] | None = None) -> Relation:
    """Natural equi-join on shared variables (sort-merge)."""
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on:  # cartesian product
        na, nb = a.n, b.n
        out = Relation()
        ia = np.repeat(np.arange(na), nb)
        ib = np.tile(np.arange(nb), na)
        for k, v in a.items():
            out[k] = v[ia]
        for k, v in b.items():
            out[k] = v[ib]
        return out
    if a.n == 0 or b.n == 0:
        return Relation.empty(sorted(set(a) | set(b)))
    # sort both sides by the composite key
    oa = _composite_key(a, on)
    ob = _composite_key(b, on)
    a_sorted = a.take(oa)
    b_sorted = b.take(ob)
    # dense-rank the key domain on the union so searchsorted works per-column
    ka = _rank_rows(a_sorted, b_sorted, on)
    kb = _rank_rows(b_sorted, a_sorted, on)
    lo = np.searchsorted(kb, ka, "left")
    hi = np.searchsorted(kb, ka, "right")
    cnt = hi - lo
    ia = np.repeat(np.arange(a_sorted.n), cnt)
    ib = _expand_ranges(lo, hi)
    out = Relation()
    for k, v in a_sorted.items():
        out[k] = v[ia]
    for k, v in b_sorted.items():
        if k not in out:
            out[k] = v[ib]
    return out


def _rank_rows(x: Relation, other: Relation, on: list[str]) -> np.ndarray:
    """Map composite keys to comparable scalars via shared dense ranking."""
    both = [np.concatenate([x[c], other[c]]) for c in on]
    nx = x.n
    key = np.zeros(len(both[0]), dtype=np.int64)
    for col in both:
        uniq, inv = np.unique(col, return_inverse=True)
        key = key * np.int64(len(uniq)) + inv  # may wrap for huge domains;
        # domain sizes here are bounded by block cardinalities (<2^20 each)
    return key[:nx]


def _expand_ranges(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Concatenate arange(lo[i], hi[i]) for all i, vectorized."""
    cnt = hi - lo
    nz = cnt > 0
    l, c = lo[nz], cnt[nz]
    total = int(c.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    out[0] = l[0]
    if len(l) > 1:
        pos = np.cumsum(c)[:-1]
        out[pos] = l[1:] - (l[:-1] + c[:-1] - 1)
    return np.cumsum(out)


def semijoin(a: Relation, b: Relation, on: list[str] | None = None) -> Relation:
    """Rows of `a` that have at least one match in `b`."""
    if on is None:
        on = sorted(set(a.keys()) & set(b.keys()))
    if not on or a.n == 0:
        return a
    if b.n == 0:
        return a.take(np.empty(0, dtype=np.int64))
    ob = _composite_key(b, on)
    b_sorted = b.take(ob)
    ka = _rank_rows(a, b_sorted, on)
    kb = _rank_rows(b_sorted, a, on)
    kb_sorted = np.sort(kb)
    pos = np.searchsorted(kb_sorted, ka)
    pos = np.clip(pos, 0, len(kb_sorted) - 1)
    hit = kb_sorted[pos] == ka
    return a.take(np.flatnonzero(hit))


def filter_in_ranges(rel: Relation, col: str, intervals: np.ndarray,
                     explicit: np.ndarray) -> Relation:
    """SIP filter (paper §3.2.2): keep rows whose `col` id lies in any I-Range
    interval or equals an E-list id. Intervals are closed [lo, hi] rows."""
    if rel.n == 0 or (len(intervals) == 0 and len(explicit) == 0):
        return rel if (len(intervals) or len(explicit)) else rel.take(
            np.empty(0, dtype=np.int64))
    vals = rel[col]
    keep = np.zeros(rel.n, dtype=bool)
    if len(intervals):
        # sort by start and take the running max of ends so OVERLAPPING
        # intervals are handled (v is in the union iff the max end among
        # intervals starting <= v covers it). V* intervals are disjoint by
        # construction, but the general case must hold too.
        iv = intervals[np.argsort(intervals[:, 0])]
        starts = iv[:, 0]
        ends = np.maximum.accumulate(iv[:, 1])
        pos = np.searchsorted(starts, vals, "right") - 1
        ok = pos >= 0
        keep[ok] = vals[ok] <= ends[np.clip(pos[ok], 0, len(ends) - 1)]
    if len(explicit):
        pos = np.searchsorted(explicit, vals)
        pos = np.clip(pos, 0, len(explicit) - 1)
        keep |= explicit[pos] == vals
    return rel.take(np.flatnonzero(keep))
