"""STREAK core: the paper's contribution as a composable library.

Public surface:
- index: SQuadTree (squadtree), identifier codec (ids), Z-order (morton),
  characteristic sets + Blooms (charsets), node selection DP (node_select)
- storage: QuadStore + permutation/numeric indexes (store), dictionary
- engine: Query AST (query), planner, APS (aps), block executor (executor),
  top-k (topk), spatial join phases (spatial_join)
- baselines: sync R-tree join, full-scan engine (baselines, rtree)
- fault tolerance: failover chains, breakers, deadlines, injection (fault)
- scale-out: Morton-prefix sharding + compressed E-list tier (shard)
"""
from .executor import ExecConfig, ExecStats, StreakEngine  # noqa: F401
from .fault import FaultPlan, FaultRule, QueryDeadline  # noqa: F401
from .join import Relation  # noqa: F401
from .policy import BackendPolicy  # noqa: F401
from .query import Query, Ranking, SpatialFilter, TriplePattern, Var  # noqa: F401
from .shard import ShardedQuadStore, shard_store  # noqa: F401
from .store import QuadStore, build_store  # noqa: F401

__all__ = [
    "BackendPolicy", "ExecConfig", "ExecStats", "FaultPlan", "FaultRule",
    "Query", "QuadStore", "QueryDeadline", "Ranking", "Relation",
    "ShardedQuadStore", "SpatialFilter", "StreakEngine", "TriplePattern",
    "Var", "build_store", "shard_store",
]
