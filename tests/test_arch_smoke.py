"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 arch config modules carries a REDUCED config of the same
family (SMOKE); here we instantiate it and run one forward/train step on
CPU asserting output shapes and no NaNs. The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry


def _finite(x):
    assert np.isfinite(np.asarray(x, dtype=np.float32)).all()


@pytest.mark.parametrize("arch_id", list(registry.ARCHS))
def test_smoke_one_step(arch_id):
    mod = registry.get(arch_id)
    cfg = mod.SMOKE
    fam = mod.FAMILY
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    if fam in ("lm", "moe"):
        from repro.models import moe as moe_m, transformer as tr
        m = moe_m if fam == "moe" else tr
        params = m.init_params(key, cfg)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)), jnp.int32)
        loss, grads = jax.value_and_grad(m.lm_loss)(params, tokens, cfg)
        _finite(loss)
        _finite(grads["embed"])
        # decode path
        cache = m.init_cache(cfg, 2, 8)
        logits, cache = m.decode_step(params, cache, tokens[:, 0],
                                      jnp.zeros(2, jnp.int32), cfg)
        assert logits.shape == (2, cfg.vocab)
        _finite(logits)
    elif fam == "gnn":
        from repro.models import gnn
        params = gnn.init_params(key, cfg)
        x = jnp.asarray(rng.normal(size=(40, cfg.d_in)).astype(np.float32))
        edges = jnp.asarray(rng.integers(0, 40, (2, 120)), jnp.int32)
        out = gnn.forward(params, x, edges, cfg)
        assert out.shape == (40, cfg.d_out)
        _finite(out)
    elif fam == "graphcast":
        from repro.models import graphcast
        params = graphcast.init_params(key, cfg)
        n_grid, n_mesh = 30, 8
        gx = jnp.asarray(rng.normal(size=(n_grid, cfg.n_vars))
                         .astype(np.float32))
        g2m = jnp.asarray(np.stack([rng.integers(0, n_grid, 60),
                                    rng.integers(0, n_mesh, 60)]), jnp.int32)
        me = jnp.asarray(rng.integers(0, n_mesh, (2, 40)), jnp.int32)
        m2g = jnp.asarray(np.stack([rng.integers(0, n_mesh, 60),
                                    rng.integers(0, n_grid, 60)]), jnp.int32)
        out = graphcast.forward(params, gx, g2m, me, m2g, n_mesh, cfg)
        assert out.shape == (n_grid, cfg.n_vars)
        _finite(out)
    elif fam == "nequip":
        from repro.models import equivariant
        params = equivariant.init_params(key, cfg)
        pos = rng.normal(size=(10, 3)).astype(np.float32) * 2
        d = np.sqrt(((pos[:, None] - pos[None]) ** 2).sum(-1))
        i, j = np.nonzero((d < cfg.cutoff) & (d > 0))
        e = equivariant.forward(params, jnp.asarray(rng.integers(
            0, cfg.n_species, 10), jnp.int32), jnp.asarray(pos),
            jnp.asarray(np.stack([i, j]), jnp.int32), cfg)
        _finite(e)
    elif fam == "recsys":
        from repro.models import sasrec
        params = sasrec.init_params(key, cfg)
        seq = jnp.asarray(rng.integers(1, cfg.n_items, (3, cfg.seq_len)),
                          jnp.int32)
        st = sasrec.user_state(params, seq, cfg)
        assert st.shape == (3, cfg.embed_dim)
        _finite(st)
    else:
        raise AssertionError(fam)


def test_full_configs_match_assignment():
    """Pin the EXACT assigned hyperparameters (regression guard)."""
    c = registry.get("nemotron-4-15b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.act) == (32, 6144, 48, 8, 24576, 256000, "sq_relu")
    c = registry.get("codeqwen1.5-7b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 32, 13440, 92416)
    c = registry.get("gemma-7b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.head_dim, c.d_ff,
            c.vocab) == (28, 3072, 16, 256, 24576, 256000)
    c = registry.get("qwen2-moe-a2.7b").CONFIG
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.d_ff_expert,
            c.n_shared, c.vocab) == (24, 2048, 60, 4, 1408, 4, 151936)
    c = registry.get("qwen3-moe-30b-a3b").CONFIG
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.n_experts, c.top_k,
            c.d_ff_expert, c.vocab) == (48, 2048, 4, 128, 8, 768, 151936)
    c = registry.get("gcn-cora").CONFIG
    assert (c.n_layers, c.d_hidden) == (2, 16)
    c = registry.get("graphcast").CONFIG
    assert (c.n_layers, c.d_hidden, c.mesh_refinement, c.n_vars) \
        == (16, 512, 6, 227)
    c = registry.get("graphsage-reddit").CONFIG
    assert (c.n_layers, c.d_hidden, c.sample_sizes) == (2, 128, (25, 10))
    c = registry.get("nequip").CONFIG
    assert (c.n_layers, c.n_channels, c.l_max, c.n_rbf, c.cutoff) \
        == (5, 32, 2, 8, 5.0)
    c = registry.get("sasrec").CONFIG
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (50, 2, 1, 50)


def test_all_cells_enumerate_40():
    assert len(registry.all_cells()) == 40
