"""graphsage-reddit [arXiv:1706.02216; paper]: 2L d_hidden=128 mean agg,
neighbor sampling 25-10."""
from ..models.gnn import GNNConfig
from .registry import GNN_SHAPES as SHAPES  # noqa: F401

FAMILY = "gnn"
CONFIG = GNNConfig(name="graphsage-reddit", arch="sage", n_layers=2,
                   d_in=602, d_hidden=128, d_out=41, aggregator="mean",
                   sample_sizes=(25, 10))
SMOKE = GNNConfig(name="graphsage-smoke", arch="sage", n_layers=2, d_in=32,
                  d_hidden=16, d_out=4, aggregator="mean",
                  sample_sizes=(5, 3))
