"""End-to-end LM training driver with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

`100m` is a ~115M-parameter GQA/SwiGLU transformer (real-run preset, slow on
CPU); `tiny` exercises the same code path in seconds. Training is resumable:
re-running the same command continues from the latest checkpoint.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data.tokens import TokenStream
from repro.models import transformer
from repro.train import loop, optim

PRESETS = {
    "tiny": dict(cfg=transformer.TransformerConfig(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab=512, dtype="float32", remat=False,
        loss_chunks=1), batch=8, seq=64),
    "100m": dict(cfg=transformer.TransformerConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768, dtype="float32", remat=True,
        loss_chunks=4), batch=8, seq=512),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = p["cfg"]
    print(f"{cfg.name}: {cfg.n_params()/1e6:.1f}M params")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(cfg.vocab, p["seq"], p["batch"], seed=0)

    def loss_fn(prm, batch):
        return transformer.lm_loss(prm, batch, cfg)

    tcfg = loop.TrainerConfig(
        ckpt_dir=f"{args.ckpt_dir}_{args.preset}", ckpt_every=25,
        log_every=10, compress_grads=args.compress_grads)
    tr = loop.Trainer(loss_fn, params, tcfg,
                      optim.AdamWConfig(lr=3e-4, warmup_steps=20,
                                        total_steps=max(args.steps, 100)))
    if tr.maybe_restore():
        print(f"resumed from step {tr.step}")
    hist = tr.fit(lambda s: (jnp.asarray(stream.batch(s)),),
                  n_steps=args.steps)
    print(f"done: step {tr.step}, loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
