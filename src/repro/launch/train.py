"""Cluster training launcher: any registered arch on the local mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
        --steps 20 [--compress-grads] [--ckpt-dir /tmp/ck]

`--smoke` uses the arch's reduced config (CPU-runnable); without it the
FULL assigned config is instantiated — only do that on real hardware. The
loop is the fault-tolerant Trainer (checkpoint/restart, straggler guard);
data comes from the family's synthetic pipeline.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import registry
from ..data import graphs, recsys, tokens
from ..train import loop, optim


def _lm_setup(mod, cfg, batch, seq):
    from ..models import moe as moe_m, transformer as tr
    m = moe_m if mod.FAMILY == "moe" else tr
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    stream = tokens.TokenStream(cfg.vocab, seq, batch, seed=0)

    def loss_fn(p, batch_):
        return m.lm_loss(p, batch_, cfg)

    return params, loss_fn, lambda s: (jnp.asarray(stream.batch(s)),)


def _gnn_setup(cfg):
    from ..models import gnn
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n = 512
    edges = graphs.random_power_law_graph(n, 8, seed=0)
    x = jnp.asarray(rng.normal(size=(n, cfg.d_in)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.d_out, n).astype(np.int32))
    mask = jnp.ones(n, dtype=bool)
    e = jnp.asarray(edges)

    def loss_fn(p, _unused):
        return gnn.nll_loss(p, x, e, labels, mask, cfg)

    return params, loss_fn, lambda s: (jnp.zeros(()),)


def _sasrec_setup(cfg, batch):
    from ..models import sasrec
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    stream = recsys.InteractionStream(cfg.n_items, cfg.seq_len, batch, seed=0)

    def loss_fn(p, seq, pos, neg):
        return sasrec.bpr_loss(p, seq, pos, neg, cfg)

    return params, loss_fn, \
        lambda s: tuple(jnp.asarray(x) for x in stream.batch(s))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    mod = registry.get(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    fam = mod.FAMILY
    if fam in ("lm", "moe"):
        params, loss_fn, batches = _lm_setup(mod, cfg, args.batch, args.seq)
    elif fam in ("gnn",):
        params, loss_fn, batches = _gnn_setup(cfg)
    elif fam == "recsys":
        params, loss_fn, batches = _sasrec_setup(cfg, args.batch)
    else:
        raise SystemExit(f"{args.arch}: use examples/ drivers for {fam}")

    tr = loop.Trainer(
        loss_fn, params,
        loop.TrainerConfig(ckpt_dir=f"{args.ckpt_dir}_{args.arch}",
                           ckpt_every=max(args.steps // 2, 1), log_every=5,
                           compress_grads=args.compress_grads),
        optim.AdamWConfig(warmup_steps=5, total_steps=max(args.steps, 50)))
    if tr.maybe_restore():
        print(f"resumed at step {tr.step}")
    hist = tr.fit(batches, n_steps=args.steps)
    print(f"{args.arch}: loss {hist[0]:.4f} -> {hist[-1]:.4f} "
          f"({tr.step} steps)")


if __name__ == "__main__":
    main()
