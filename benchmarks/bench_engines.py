"""Fig. 10/11: end-to-end STREAK vs the full-scan engine (PostgreSQL-like).

"Cold" = fresh engine (no pattern-scan cache); "warm" = second run with the
scan cache populated (the paper's cold/warm distinction is filesystem cache;
ours is the in-memory scan cache, same role).
"""
from __future__ import annotations

from repro import StreakEngine
from repro.core.baselines import FullScanEngine

from . import common


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            t_cold = common.timeit(
                lambda: StreakEngine(ds.store).execute(q), warmup=0, repeat=3)
            warm_eng = StreakEngine(ds.store)
            t_warm = common.timeit(lambda: warm_eng.execute(q))
            t_full = common.timeit(
                lambda: FullScanEngine(ds.store).execute(q), warmup=0,
                repeat=3)
            rows.append(common.row(
                f"fig10_engines/{ds_name}/Q{qi+1}_streak_warm", t_warm,
                f"speedup_vs_fullscan={t_full/max(t_warm,1):.1f}x"))
            rows.append(common.row(
                f"fig11_engines/{ds_name}/Q{qi+1}_streak_cold", t_cold, ""))
            rows.append(common.row(
                f"fig10_engines/{ds_name}/Q{qi+1}_fullscan", t_full, ""))
    return rows
