"""Tables 1/3 + the store-size scaling curve (1M -> 100M synthetic quads).

The `scale/` section builds `synth_rdf.make_scale` datasets at increasing
quad counts and reports, per size: build time, store/tree bytes, the
Morton-prefix sharded store's per-shard bytes with the compressed E-list
tier (`PackedEList`) against the uncompressed tier, and per-query engine
latency unsharded vs 4-way sharded — with the sharded results asserted
identical to the unsharded engine before anything is timed.

Default sizes stop at 10M so the committed BENCH_sizes.json stays
reproducible in CI-class time; set ``REPRO_BENCH_SIZES`` (comma-separated
quad counts, e.g. ``1000000,100000000``) to sweep the full curve.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro import ExecConfig, StreakEngine
from repro.core.shard import shard_store
from repro.data import synth_rdf

from . import common

DEFAULT_SIZES = (1_000_000, 3_000_000, 10_000_000)
N_SHARDS = 4


def _sizes() -> tuple:
    env = os.environ.get("REPRO_BENCH_SIZES")
    if not env:
        return DEFAULT_SIZES
    return tuple(int(s) for s in env.split(",") if s.strip())


def scaling_curve() -> list:
    rows = []
    for n_quads in _sizes():
        t0 = time.time()
        ds = synth_rdf.make_scale(n_quads, seed=0)
        build_s = time.time() - t0
        store, tree = ds.store, ds.store.tree
        t0 = time.time()
        sharded = shard_store(store, N_SHARDS, compressed=True)
        shard_s = time.time() - t0

        # compressed E-list tier vs the plain int64 tier, same trees: the
        # packed encoding records the id counts, so the uncompressed bytes
        # are known without a second build
        packed_b = sum(sh.tree.packed.nbytes()
                       for sh in sharded.tree_shards)
        plain_b = sum(int(sh.tree.packed.counts.sum(dtype=np.int64)) * 8
                      for sh in sharded.tree_shards)
        tree_b = sharded.shard_tree_nbytes()
        tree_plain_b = tree_b - packed_b + plain_b
        tag = f"scale/n{n_quads}"
        rows.append(common.row(
            f"{tag}/build", build_s * 1e6,
            f"quads={store.n_quads};spatial={tree.n_objects};"
            f"nodes={tree.n_nodes};shard_build_s={shard_s:.1f}"))
        rows.append(common.row(
            f"{tag}/bytes", 0.0,
            f"store_mb={store.nbytes() / 2**20:.1f};"
            f"tree_mb={tree.nbytes() / 2**20:.2f};"
            f"shard_tree_mb={tree_b / 2**20:.2f};"
            f"shard_tree_plain_mb={tree_plain_b / 2**20:.2f};"
            f"elist_packed_mb={packed_b / 2**20:.2f};"
            f"elist_plain_mb={plain_b / 2**20:.2f};"
            f"elist_ratio={plain_b / max(packed_b, 1):.2f}x;"
            f"tree_ratio={tree_plain_b / max(tree_b, 1):.2f}x"))

        eng = StreakEngine(store, ExecConfig())
        eng_sh = StreakEngine(sharded, ExecConfig())
        for qi, q in enumerate(ds.queries):
            s0, r0, _ = eng.execute(q)
            s1, r1, _ = eng_sh.execute(q)
            np.testing.assert_array_equal(np.sort(s1), np.sort(s0))
            assert r1.n == r0.n
            t = common.timeit(lambda: eng.execute(q), warmup=1, repeat=1)
            t_sh = common.timeit(lambda: eng_sh.execute(q), warmup=1,
                                 repeat=1)
            rows.append(common.row(f"{tag}/Q{qi + 1}_unsharded", t,
                                   f"rows={r0.n}"))
            rows.append(common.row(
                f"{tag}/Q{qi + 1}_sharded{N_SHARDS}", t_sh,
                f"rows={r1.n};speedup={t / max(t_sh, 1e-9):.2f}x"))
    return rows


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        store = ds.store
        tree = store.tree
        rows.append(common.row(
            f"table1_data/{ds_name}", 0.0,
            f"quads={store.n_quads};spatial={tree.n_objects};"
            f"nodes={tree.n_nodes}"))
        rows.append(common.row(
            f"table3_sizes/{ds_name}", 0.0,
            f"raw_mb={ds.raw_nbytes/2**20:.1f};"
            f"store_mb={store.nbytes()/2**20:.1f};"
            f"squadtree_mb={tree.nbytes()/2**20:.2f};"
            f"tree_frac={tree.nbytes()/max(ds.raw_nbytes,1)*100:.2f}%"))
    rows += scaling_curve()
    return rows
