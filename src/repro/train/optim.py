"""AdamW with fp32 moments + cosine schedule + global-norm clipping.

Written against raw pytrees (no optax dependency in this container). Moments
shard exactly like their parameters (dist/partitioning.like_params).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(step.astype(jnp.float32), cfg)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [n[0] for n in new])
    m = jax.tree.unflatten(treedef, [n[1] for n in new])
    v = jax.tree.unflatten(treedef, [n[2] for n in new])
    return params, {"m": m, "v": v, "step": step}, {"grad_norm": gn, "lr": lr}
