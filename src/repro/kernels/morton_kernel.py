"""Pallas TPU kernel: Z-order (Morton) bit interleave.

Identifier assignment and query-side cell bucketing encode 16-bit cell
coordinates into Morton codes. Pure VPU bit manipulation over (R, 128)
lane-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spread(v):
    v = v.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def _kernel(cx_ref, cy_ref, out_ref):
    out_ref[...] = (_spread(cx_ref[...])
                    | (_spread(cy_ref[...]) << 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def morton_encode(cx: jnp.ndarray, cy: jnp.ndarray, rows: int = 8,
                  interpret: bool = False) -> jnp.ndarray:
    """cx, cy int32 cell coords (n,) -> morton codes int32 (n,)."""
    n = cx.shape[0]
    lane = 128
    tile = rows * lane
    npad = -(-n // tile) * tile
    cx_p = jnp.pad(cx.astype(jnp.int32), (0, npad - n)).reshape(-1, lane)
    cy_p = jnp.pad(cy.astype(jnp.int32), (0, npad - n)).reshape(-1, lane)
    grid = (cx_p.shape[0] // rows,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, lane), lambda i: (i, 0)),
                  pl.BlockSpec((rows, lane), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(cx_p.shape, jnp.int32),
        interpret=interpret,
    )(cx_p, cy_p)
    return out.reshape(-1)[:n]
