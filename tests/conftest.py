"""Test-session bootstrap.

If the real `hypothesis` package is missing (it is pinned in
requirements-dev.txt, but bare environments may lack it), register the
random-sampling fallback from tests/_hypothesis_fallback.py under the
`hypothesis` module name so the property-test modules still collect and run.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    import _hypothesis_fallback

    _mod = _hypothesis_fallback.install()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
