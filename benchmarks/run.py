"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. See DESIGN.md §6 for the
paper-artifact -> benchmark index.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_aps, bench_engines, bench_join, bench_kernels,
                   bench_sip, bench_sizes, bench_vary_k)
    suites = [
        ("table1/3 sizes", bench_sizes),
        ("fig7 SIP", bench_sip),
        ("fig8 join algorithms", bench_join),
        ("fig9 APS", bench_aps),
        ("fig10/11 engines", bench_engines),
        ("fig12 vary k", bench_vary_k),
        ("kernels", bench_kernels),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for label, mod in suites:
        if only and only not in label and only not in mod.__name__:
            continue
        t0 = time.time()
        for row in mod.run():
            print(row)
        print(f"# {label}: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
