"""Elastic resharding: survive device loss without restarting training.

When a host drops out, the job shrinks the data-parallel axis (the model
axis must keep its size — parameters are sharded across it), re-derives each
array's PartitionSpec on the surviving mesh, and device_puts the state over.
`respec` also folds away mesh axes that no longer exist (e.g. the "pod" axis
when a 2-pod job collapses to one pod).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..launch.mesh import _axis_kwargs


def _compat_mesh(devices: np.ndarray, axis_names: tuple) -> Mesh:
    """Mesh construction across jax versions (axis_types is recent API)."""
    return Mesh(devices, axis_names, **_axis_kwargs(len(axis_names)))


def shrink_mesh(mesh: Mesh, n_lost: int, model_axis: str = "model") -> Mesh:
    """New mesh over the surviving devices, preserving the model axis size.

    Only the non-model axes shrink: with `model` parameters sharded across
    `model_axis`, dropping model shards would lose state. The data axis is
    rounded down to the largest size that fits the surviving device count.
    """
    names = tuple(mesh.axis_names)
    model = int(mesh.shape[model_axis]) if model_axis in names else 1
    alive = int(mesh.devices.size) - int(n_lost)
    rows = max(1, alive // model)
    flat = mesh.devices.reshape(-1)[: rows * model]
    other = tuple(n for n in names if n != model_axis)
    if len(other) == 1:
        shape = (rows, model) if names.index(model_axis) == 1 else (model, rows)
        return _compat_mesh(flat.reshape(shape), names)
    # collapse any extra leading axes (e.g. "pod") into the first data axis
    new_names = (other[-1], model_axis) if model_axis in names else other
    return _compat_mesh(flat.reshape(rows, model), new_names)


def respec(sharding: NamedSharding, new_mesh: Mesh) -> NamedSharding:
    """Re-derive a NamedSharding on `new_mesh`, dropping vanished axes.

    Spec entries may be axis names or tuples of names; names absent from the
    new mesh (a folded "pod" axis) are removed, and an entry left empty
    becomes replication (None).
    """
    alive = set(new_mesh.axis_names)
    new_entries = []
    for entry in sharding.spec:
        if entry is None:
            new_entries.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in alive)
            new_entries.append(kept if kept else None)
        else:
            new_entries.append(entry if entry in alive else None)
    return NamedSharding(new_mesh, PartitionSpec(*new_entries))


def reshard_tree(tree, shardings, new_mesh: Mesh):
    """device_put every leaf onto `new_mesh` under its respec'd sharding.

    `shardings` mirrors `tree` (a pytree of NamedShardings, e.g. captured
    from the live arrays before the failure).
    """
    return jax.tree.map(
        lambda x, s: jax.device_put(x, respec(s, new_mesh)), tree, shardings)
