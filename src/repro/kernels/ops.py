"""Public jit'd wrappers for the Pallas kernels with CPU fallbacks.

On TPU the Pallas path compiles natively; on CPU we use interpret mode (for
tests) or the jnp reference (for the engine's `kernel` backend), keeping one
call site for both worlds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import block_scan as _bs
from . import bloom_probe as _bp
from . import distance_join as _dj
from . import flash_attention as _fa
from . import fused_topk_join as _ftj
from . import geom_refine as _gr
from . import morton_kernel as _mk
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def distance_join_matrix(driver, driven, interpret: bool | None = None):
    driver = jnp.asarray(driver, dtype=jnp.float32)
    driven = jnp.asarray(driven, dtype=jnp.float32)
    if _on_tpu() or interpret:
        return _dj.distance_join(driver, driven,
                                 interpret=bool(interpret) and not _on_tpu())
    return ref.distance_join_ref(driver, driven)


def distance_join_mask(driver, driven, dist: float,
                       interpret: bool | None = None):
    return distance_join_matrix(driver, driven, interpret) <= dist


def fused_topk_join(driver, driven, driver_keys, driven_keys,
                    dist: float, theta: float, k: int = 64,
                    interpret: bool | None = None):
    """Streaming per-row top-k distance join; see kernels/fused_topk_join.py.

    Returns (scores (M, k), idx (M, k), counts (M,)) — the per-row partials
    the `fused` join backend consumes. On CPU without interpret mode this
    runs the dense jnp oracle (still per column *batch* when called through
    core/spatial_join.py, so peak memory stays independent of total N).
    """
    driver = jnp.asarray(driver, dtype=jnp.float32)
    driven = jnp.asarray(driven, dtype=jnp.float32)
    dk = jnp.asarray(driver_keys, dtype=jnp.float32)
    vk = jnp.asarray(driven_keys, dtype=jnp.float32)
    if _on_tpu() or interpret:
        return _ftj.fused_topk_join(
            driver, driven, dk, vk, dist, theta, k=k,
            interpret=bool(interpret) and not _on_tpu())
    return _fused_ref_jit(driver, driven, dk, vk,
                          jnp.float32(dist), jnp.float32(theta), k)


@functools.partial(jax.jit, static_argnames=("k",))
def _fused_ref_jit(driver, driven, dk, vk, dist, theta, k):
    return ref.fused_topk_join_ref(driver, driven, dk, vk, dist, theta, k)


def bucketed_min_core(a_planes, b_planes, interpret: bool | None = None):
    """Per-pair exact-geometry min squared distance over one padded
    size-class bucket; see kernels/geom_refine.py. a_planes / b_planes:
    dims-tuples of (B, m_pad) / (B, n_pad) float32 coordinate planes whose
    padding replicates real points (dims=2 raw x/y for euclid, dims=3
    unit-sphere X/Y/Z for haversine). Returns (B,) float32 core minima —
    the caller applies the metric's monotone distance transform in float64
    (core/spatial_join.py::core_to_dist)."""
    a_planes = tuple(jnp.asarray(p, dtype=jnp.float32) for p in a_planes)
    b_planes = tuple(jnp.asarray(p, dtype=jnp.float32) for p in b_planes)
    if _on_tpu() or interpret:
        return _gr.bucketed_min_core(
            a_planes, b_planes,
            interpret=bool(interpret) and not _on_tpu())
    # CPU: the loop-structured host twin (kernel numerics, no (B, m, n)
    # cube); ref.bucketed_min_core_ref stays the test oracle
    return _gr.bucketed_min_core_host(a_planes, b_planes)


def bloom_probe(bits, keys, k: int = 3, interpret: bool | None = None):
    """bits (B, W) uint32 pre-gathered filter rows; keys (B,) int64."""
    keys = np.asarray(keys, dtype=np.int64).view(np.uint64)
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
                     .view(np.int32))
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32).view(np.int32))
    bits = jnp.asarray(bits)
    if _on_tpu() or interpret:
        return _bp.bloom_probe(bits, lo, hi, k=k,
                               interpret=bool(interpret) and not _on_tpu()) == 1
    return ref.bloom_probe_ref(bits, lo, hi, k)


def block_scan(scores, theta: float, interpret: bool | None = None):
    scores = jnp.asarray(scores, dtype=jnp.float32)
    if _on_tpu() or interpret:
        return _bs.block_scan(scores, theta,
                              interpret=bool(interpret) and not _on_tpu())
    return ref.block_scan_ref(scores, theta)


def morton_encode(cx, cy, interpret: bool | None = None):
    cx = jnp.asarray(cx, dtype=jnp.int32)
    cy = jnp.asarray(cy, dtype=jnp.int32)
    if _on_tpu() or interpret:
        return _mk.morton_encode(cx, cy,
                                 interpret=bool(interpret) and not _on_tpu())
    return ref.morton_ref(cx, cy)


def flash_attention(q, k, v, causal: bool = True,
                    interpret: bool | None = None):
    if _on_tpu() or interpret:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   interpret=bool(interpret) and not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)
