"""Fig. 8: candidate pairs, S-QuadTree join vs synchronous R-tree traversal.

The paper's key index ablation: same block pipeline, the spatial join
swapped. We report MBR-level candidate counts (lower = better pruning) and
end-to-end time.
"""
from __future__ import annotations

from repro.core.baselines import SyncRTreeEngine
from repro.core.executor import ExecConfig, StreakEngine

from . import common


def run() -> list:
    rows = []
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            squad = StreakEngine(ds.store, ExecConfig(force_plan="S"))
            rtree = SyncRTreeEngine(ds.store)
            _, _, st_q = squad.execute(q)
            _, _, st_r = rtree.execute(q)
            t_q = common.timeit(lambda: squad.execute(q))
            t_r = common.timeit(lambda: rtree.execute(q))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_squadtree", t_q,
                f"cands={st_q.join.candidates}"))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_sync_rtree", t_r,
                f"cands={st_r.join.candidates};"
                f"ratio={st_r.join.candidates/max(st_q.join.candidates,1):.1f}x"))
    return rows
