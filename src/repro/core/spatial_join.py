"""The block spatial join: Phases 1-3 + refinement (paper §3.2).

Phase 1 (candidate nodes) lives on SQuadTree.candidate_nodes; Phase 2 is
node_select.select + SIP filter material; this module is Phase 3 — the
pairwise MBR distance join between a driver block and the SIP-filtered driven
candidates — plus the exact-geometry refinement step.

The MBR join is the compute hot spot. Three backends:

- ``numpy``  — dense broadcast via geometry.box_min_dist; the portable
  fallback and the oracle for tests.
- ``kernel`` — the tiled Pallas matrix kernel (kernels/distance_join.py):
  materializes the full (M, N) distance matrix, the caller masks it.
- ``fused``  — the streaming top-k kernel (kernels/fused_topk_join.py):
  driven entities are fed in score-key order, each column batch is reduced
  in VMEM to per-row top-k partials under the current top-k threshold θ, and
  the (M, N) matrix never exists. `fused_stream_join` below is the driver:
  it re-reads θ between batches (so early termination prunes *inside* an
  executor block) and recovers overflowing rows densely so the candidate
  set stays exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import geometry, topk as topk_mod

# Phase-3 MBR-join backend registry (see module docstring). "auto" resolves
# to the dense numpy broadcast: the kernel path pays (M, N) materialization
# through jax and the fused path only wins with real score keys + a live θ,
# which the executor supplies explicitly when configured.
JOIN_BACKENDS = ("auto", "numpy", "kernel", "fused")


def resolve_join_backend(backend: str | None) -> str:
    b = backend or "auto"
    if b not in JOIN_BACKENDS:
        raise ValueError(f"unknown spatial join backend {b!r}")
    return "numpy" if b == "auto" else b


@dataclasses.dataclass
class JoinStats:
    candidates: int = 0       # MBR-level candidate pairs emitted
    refined: int = 0          # pairs surviving exact refinement
    pairs_tested: int = 0     # full MBR pairs evaluated (block product)
    refine_skipped: int = 0   # candidate pairs never refined (θ-aware skip)
    overflow_rows: int = 0    # driver rows recovered densely (partial width
    #                           overflow in the fused kernel)
    overflow_batches: int = 0  # column batches with >= 1 overflowing row


@dataclasses.dataclass
class KcapTuner:
    """EWMA autotuner for the fused kernel's per-row partial width.

    The fixed ``min(max(k, 64), batch_cols)`` floor pays worst-case partial
    widths on every launch even when θ has tightened enough that almost no
    pairs survive. The tuner tracks an EWMA of the observed per-launch MAX
    survivor count and suggests ``headroom`` times that, quantized to the
    next power of two (bounding jit recompiles to one per pow2 class) and
    clamped to ``[max(k, floor), min(ceiling, batch_cols)]``. Undershooting
    a survivor burst is *safe* — overflowing rows are recovered densely by
    the caller (see fused_stream_join) — it only costs recompute, which
    JoinStats.overflow_* makes observable.
    """
    alpha: float = 0.25       # EWMA smoothing weight for the newest sample
    headroom: float = 1.5     # width multiplier over the smoothed max
    floor: int = 8            # never suggest below this (absent a larger k)
    ceiling: int = 1024       # never suggest above this
    ewma: float | None = None

    def update(self, counts: np.ndarray) -> None:
        """Fold one launch's per-row survivor counts into the EWMA."""
        if len(counts) == 0:
            return
        obs = float(np.max(counts))
        self.ewma = obs if self.ewma is None else (
            self.alpha * obs + (1.0 - self.alpha) * self.ewma)

    def suggest(self, k: int, batch_cols: int) -> int:
        if self.ewma is None:               # cold start: the old fixed floor
            width = max(int(k), 64)
        else:
            width = int(np.ceil(self.ewma * self.headroom))
        width = max(width, int(k), self.floor)
        width = 1 << max(int(width - 1).bit_length(), 0)   # next pow2
        return int(max(min(width, self.ceiling, batch_cols),
                       min(self.floor, batch_cols)))


def mbr_distance_join(driver_boxes: np.ndarray, driven_boxes: np.ndarray,
                      dist_norm: float, backend: str = "numpy",
                      stats: JoinStats | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Candidate pairs (i, j) with box_min_dist <= dist (normalized space)."""
    if len(driver_boxes) == 0 or len(driven_boxes) == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if backend == "fused":
        # pure-distance use of the streaming kernel: zero keys, θ = -inf.
        # With nothing to prune this does MORE work than the matrix paths —
        # it exists for drop-in equivalence (tests, ablations); the perf
        # path is fused_stream_join with real keys via the executor.
        pi, pj = [], []
        for bi, bj in fused_stream_join(
                driver_boxes, driven_boxes,
                np.zeros(len(driver_boxes)), np.zeros(len(driven_boxes)),
                dist_norm, k=64, stats=stats):
            pi.append(bi)
            pj.append(bj)
        i = np.concatenate(pi) if pi else np.empty(0, np.int64)
        j = np.concatenate(pj) if pj else np.empty(0, np.int64)
        order = np.lexsort((j, i))      # match the dense row-major order
        return i[order], j[order]
    if backend == "kernel":
        from ..kernels import ops as kops
        mask = np.asarray(kops.distance_join_mask(
            driver_boxes.astype(np.float32), driven_boxes.astype(np.float32),
            float(dist_norm)))
    else:
        d = geometry.box_min_dist(driver_boxes[:, None, :],
                                  driven_boxes[None, :, :])
        mask = d <= dist_norm
    if stats is not None:
        stats.pairs_tested += mask.size
        stats.candidates += int(mask.sum())
    i, j = np.nonzero(mask)
    return i.astype(np.int64), j.astype(np.int64)


def _sanitize_keys(keys: np.ndarray, n: int) -> np.ndarray:
    """Per-entity score-key upper bounds as f32; NaN (no value -> the row can
    never produce a scored result) maps to -inf so the kernel drops it.

    Engine score keys are f64; round-to-nearest f32 conversion may round a
    bound *below* the true key, which would make θ pruning unsound. Nudge
    any rounded-down value one ulp toward +inf so the f32 bound stays a true
    upper bound (false survivors are harmless — scoring decides).
    """
    if keys is None:
        return np.zeros(n, dtype=np.float32)
    keys64 = np.asarray(keys, dtype=np.float64)
    k32 = keys64.astype(np.float32)
    low = k32.astype(np.float64) < keys64
    k32 = np.where(low, np.nextafter(k32, np.float32(np.inf)), k32)
    return np.where(np.isnan(k32), -np.inf, k32).astype(np.float32)


def _theta32_lower(theta: float) -> np.float32:
    """θ as f32 rounded toward -inf: the kernel must never prune with a θ
    above the true f64 threshold."""
    t32 = np.float32(theta)
    if np.isfinite(t32) and float(t32) > theta:
        t32 = np.nextafter(t32, np.float32(-np.inf))
    return t32


def fused_stream_join(driver_boxes: np.ndarray, driven_boxes: np.ndarray,
                      driver_keys: np.ndarray, driven_keys: np.ndarray,
                      dist_norm: float, k: int,
                      theta_fn=None, batch_cols: int = 4096,
                      interpret: bool | None = None,
                      stats: JoinStats | None = None,
                      tuner: KcapTuner | None = None):
    """Streaming Phase-3 join: yields (pi, pj) candidate batches.

    Driven entities are processed in descending score-key order, one
    `batch_cols`-wide column batch per fused-kernel call, so:

    - `theta_fn()` (the shared TopK threshold) is re-read before every batch
      and pushed into the kernel's VMEM predicate — results the caller pushes
      between batches tighten the filter mid-block;
    - once ``max(driver_keys) + driven_keys[next] <= θ`` no later pair can
      enter the top-k (keys are sorted), and the scan stops — the paper's
      early termination applied *inside* a block;
    - peak intermediate memory is O(M * batch_cols), independent of N.

    The kernel emits fixed-width (M, k) per-row partials plus exact survivor
    counts; rows whose survivors overflow the width are recovered densely
    (only those rows, only this batch), keeping the candidate set exactly
    equal to the matrix backends'. Pairs are (driver row, driven row) indices
    into the *original* (unsorted) arrays.
    """
    from ..kernels import ops as kops

    m, n = len(driver_boxes), len(driven_boxes)
    if m == 0 or n == 0:
        return
    ds = _sanitize_keys(driver_keys, m)
    vs = _sanitize_keys(driven_keys, n)
    ds_max = float(ds.max()) if m else -np.inf
    order = np.argsort(-vs, kind="stable")
    dvn_sorted = np.ascontiguousarray(driven_boxes[order], dtype=np.float32)
    vs_sorted = vs[order]
    drv = np.ascontiguousarray(driver_boxes, dtype=np.float32)

    for start in range(0, n, batch_cols):
        theta = float(theta_fn()) if theta_fn is not None else -np.inf
        # early termination inside the block: the best remaining pair bound
        # cannot beat theta, and keys only decrease from here
        if ds_max + float(vs_sorted[start]) <= theta:
            break
        # partial width: autotuned from observed survivor counts when a
        # tuner is threaded through; otherwise the fixed floor above k
        # keeps the (rare but expensive) dense overflow recovery off the
        # common path when θ is still loose
        kcap = (tuner.suggest(int(k), batch_cols) if tuner is not None
                else min(max(int(k), 64), batch_cols))
        theta32 = _theta32_lower(theta)
        chunk = dvn_sorted[start:start + batch_cols]
        ck = vs_sorted[start:start + batch_cols]
        scores, idx, counts = kops.fused_topk_join(
            drv, chunk, ds, ck, float(dist_norm), theta32, k=kcap,
            interpret=interpret)
        idx = np.asarray(idx)
        counts = np.asarray(counts)
        if tuner is not None:
            tuner.update(counts)
        if stats is not None:
            stats.pairs_tested += m * len(chunk)

        ok_rows = counts <= kcap
        sel = (idx >= 0) & ok_rows[:, None]
        pi = np.nonzero(sel)[0].astype(np.int64)
        pj_local = idx[sel].astype(np.int64)
        over = np.flatnonzero(~ok_rows)
        if len(over):
            # width overflow: recover those rows densely — same f32 arrays,
            # same f32 distance formula and θ the kernel used, so recovered
            # rows see exactly the kernel's predicate
            if stats is not None:
                stats.overflow_rows += len(over)
                stats.overflow_batches += 1
            d = np.asarray(kops.distance_join_matrix(
                drv[over], chunk, interpret=interpret))
            bound = ds[over][:, None] + ck[None, :]
            oi, oj = np.nonzero((d <= np.float32(dist_norm))
                                & (bound > theta32))
            pi = np.concatenate([pi, over[oi].astype(np.int64)])
            pj_local = np.concatenate([pj_local, oj.astype(np.int64)])
        if len(pi) == 0:
            continue
        pj = order[start + pj_local]
        srt = np.lexsort((pj, pi))
        pi, pj = pi[srt], pj[srt]
        if stats is not None:
            stats.candidates += len(pi)
        yield pi, pj


@dataclasses.dataclass
class StreamEntry:
    """One query's Phase-3 work registered with fused_stream_join_multi.

    `emit(pi, pj)` receives candidate-pair batches (indices into the
    original driver/driven arrays) and is expected to refine + push them
    into the query's TopK so the next `theta_fn()` read is tighter.

    `error` is the crash-isolation channel: an exception in one entry's
    per-span work (overflow recovery, emit/refine) lands here and retires
    only that entry from subsequent launches — the other entries' streams
    proceed. A faulted entry's TopK may hold a partial batch, so the owner
    must restart the query from a fresh cursor, not resume it.
    """
    driver_boxes: np.ndarray
    driven_boxes: np.ndarray
    driver_keys: np.ndarray
    driven_keys: np.ndarray
    dist_norm: float
    k: int
    theta_fn: object                  # () -> float, the query's live θ
    emit: object                      # (pi, pj) -> None
    stats: JoinStats | None = None
    error: Exception | None = None    # set ⟹ entry retired by a fault


def fused_stream_join_multi(entries: list[StreamEntry],
                            batch_cols: int = 4096,
                            interpret: bool | None = None,
                            tuner: KcapTuner | None = None) -> int:
    """Cross-query streaming Phase-3 join: several queries' driver blocks in
    ONE kernel grid per launch.

    Each entry is the per-query state fused_stream_join would process alone;
    here the driver rows of all live entries are concatenated (tagged with a
    per-row query id, distance threshold, and θ) and each launch takes the
    next ≈ batch_cols / n_live columns from EVERY live entry's key-sorted
    driven side. The kernel's query-id mask keeps pairs within their query,
    so per-query results are bit-identical to running fused_stream_join
    serially: same column order, same θ reads at batch granularity, same
    dense overflow recovery per (query, batch).

    Entries retire independently — when a query's remaining key bound cannot
    beat its θ (or its columns are exhausted) its rows leave the launch and
    the survivors' column share grows. Returns the number of kernel
    launches (the bench asserts batching actually happened).
    """
    from ..kernels import ops as kops

    class _Cur:
        def __init__(self, e: StreamEntry):
            self.e = e
            self.m = len(e.driver_boxes)
            self.n = len(e.driven_boxes)
            self.ds = _sanitize_keys(e.driver_keys, self.m)
            vs = _sanitize_keys(e.driven_keys, self.n)
            self.ds_max = float(self.ds.max()) if self.m else -np.inf
            self.order = np.argsort(-vs, kind="stable")
            self.dvn = np.ascontiguousarray(e.driven_boxes[self.order],
                                            dtype=np.float32)
            self.vs = vs[self.order]
            self.drv = np.ascontiguousarray(e.driver_boxes,
                                            dtype=np.float32)
            self.pos = 0

        def live(self) -> bool:
            if self.e.error is not None:
                return False
            if self.m == 0 or self.pos >= self.n:
                return False
            theta = float(self.e.theta_fn())
            return self.ds_max + float(self.vs[self.pos]) > theta

    curs = [_Cur(e) for e in entries]
    launches = 0
    while True:
        live = [c for c in curs if c.live()]
        if not live:
            break
        cols_per = max(1, batch_cols // len(live))
        kmax = max(int(c.e.k) for c in live)
        kcap = (tuner.suggest(kmax, batch_cols) if tuner is not None
                else min(max(kmax, 64), batch_cols))
        # pow2-quantize so retiring entries (shrinking kmax) don't force a
        # fresh jit signature per launch
        kcap = min(1 << max(int(kcap - 1).bit_length(), 0), batch_cols)
        # assemble the launch: driver rows / driven columns of every live
        # query, tagged with qid + per-row (dist, θ)
        drv_l, ds_l, rq_l, dist_l, th_l = [], [], [], [], []
        col_l, ck_l, cq_l = [], [], []
        spans = []                       # (cur, row_off, col_off, ncols, θ32)
        row_off = col_off = 0
        for qid, c in enumerate(live):
            ncols = min(cols_per, c.n - c.pos)
            theta32 = _theta32_lower(float(c.e.theta_fn()))
            drv_l.append(c.drv)
            ds_l.append(c.ds)
            rq_l.append(np.full(c.m, qid, np.int32))
            dist_l.append(np.full(c.m, np.float32(c.e.dist_norm)))
            th_l.append(np.full(c.m, theta32))
            col_l.append(c.dvn[c.pos:c.pos + ncols])
            ck_l.append(c.vs[c.pos:c.pos + ncols])
            cq_l.append(np.full(ncols, qid, np.int32))
            spans.append((c, row_off, col_off, ncols, theta32))
            row_off += c.m
            col_off += ncols
        # pad rows/columns up to pow2 buckets with sentinel qids (-1 rows
        # never match -2 columns, dist=-1 kills the distance predicate) so
        # per-step size drift — queries retiring, column shares growing —
        # reuses a handful of jit signatures instead of compiling each launch
        m_tot, n_tot = row_off, col_off
        m_pad = max(128, 1 << int(m_tot - 1).bit_length()) - m_tot
        n_pad = max(128, 1 << int(n_tot - 1).bit_length()) - n_tot
        if m_pad:
            drv_l.append(np.zeros((m_pad, 4), np.float32))
            ds_l.append(np.full(m_pad, -np.inf, np.float32))
            rq_l.append(np.full(m_pad, -1, np.int32))
            dist_l.append(np.full(m_pad, -1.0, np.float32))
            th_l.append(np.full(m_pad, np.inf, np.float32))
        if n_pad:
            col_l.append(np.zeros((n_pad, 4), np.float32))
            ck_l.append(np.full(n_pad, -np.inf, np.float32))
            cq_l.append(np.full(n_pad, -2, np.int32))
        try:
            scores, idx, counts = kops.fused_topk_join(
                np.concatenate(drv_l), np.concatenate(col_l),
                np.concatenate(ds_l), np.concatenate(ck_l),
                np.concatenate(dist_l), np.concatenate(th_l), k=kcap,
                row_qid=np.concatenate(rq_l), col_qid=np.concatenate(cq_l),
                interpret=interpret)
        except Exception as exc:    # noqa: BLE001 — whole-launch failure
            # the shared launch died past the failover chain: every rider
            # faults (their owners restart from fresh cursors); entries not
            # in this launch are untouched
            for c, *_ in spans:
                c.e.error = exc
            continue
        idx = np.asarray(idx)
        counts = np.asarray(counts)
        launches += 1
        if tuner is not None:
            tuner.update(counts)
        for c, r0, c0, ncols, theta32 in spans:
            e = c.e
            try:
                eidx = idx[r0:r0 + c.m]
                ecnt = counts[r0:r0 + c.m]
                if e.stats is not None:
                    e.stats.pairs_tested += c.m * ncols
                ok_rows = ecnt <= kcap
                sel = (eidx >= 0) & ok_rows[:, None]
                pi = np.nonzero(sel)[0].astype(np.int64)
                # qid masking confines survivors to this entry's column span
                pj_local = eidx[sel].astype(np.int64) - c0
                over = np.flatnonzero(~ok_rows)
                if len(over):
                    if e.stats is not None:
                        e.stats.overflow_rows += len(over)
                        e.stats.overflow_batches += 1
                    chunk = c.dvn[c.pos:c.pos + ncols]
                    ck = c.vs[c.pos:c.pos + ncols]
                    d = np.asarray(kops.distance_join_matrix(
                        c.drv[over], chunk, interpret=interpret))
                    bound = c.ds[over][:, None] + ck[None, :]
                    oi, oj = np.nonzero((d <= np.float32(e.dist_norm))
                                        & (bound > theta32))
                    pi = np.concatenate([pi, over[oi].astype(np.int64)])
                    pj_local = np.concatenate([pj_local, oj.astype(np.int64)])
                if len(pi):
                    pj = c.order[c.pos + pj_local]
                    srt = np.lexsort((pj, pi))
                    pi, pj = pi[srt], pj[srt]
                    if e.stats is not None:
                        e.stats.candidates += len(pi)
                    e.emit(pi, pj)
            except Exception as exc:    # noqa: BLE001 — per-entry isolation
                # one entry's overflow recovery / emit / refine crashed:
                # retire it (owner restarts it) and keep the others going
                e.error = exc
            c.pos += ncols
    return launches


def fused_topk_pairs(driver_boxes: np.ndarray, driven_boxes: np.ndarray,
                     driver_keys: np.ndarray, driven_keys: np.ndarray,
                     dist_norm: float, k: int, theta: float = -np.inf,
                     batch_cols: int = 4096,
                     interpret: bool | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Global per-row top-k of the fused join, without densifying.

    Runs the streaming kernel batch by batch and absorbs the per-batch
    (M, k) partials through topk.merge_row_partials (the two-level merge:
    tiles fold in-kernel, batches fold here). Returns (scores (M, k),
    idx (M, k) into the original driven array), -inf/-1 padded.
    """
    from ..kernels import ops as kops

    m, n = len(driver_boxes), len(driven_boxes)
    ds = _sanitize_keys(driver_keys, m)
    vs = _sanitize_keys(driven_keys, n)
    kcap = max(int(k), 1)
    theta32 = _theta32_lower(float(theta))
    parts = []
    for start in range(0, n, batch_cols):
        chunk = np.ascontiguousarray(
            driven_boxes[start:start + batch_cols], dtype=np.float32)
        scores, idx, _ = kops.fused_topk_join(
            np.ascontiguousarray(driver_boxes, dtype=np.float32), chunk,
            ds, vs[start:start + batch_cols], float(dist_norm), theta32,
            k=kcap, interpret=interpret)
        idx = np.asarray(idx).astype(np.int64)
        parts.append((np.asarray(scores),
                      np.where(idx >= 0, idx + start, -1)))
    if not parts:
        return (np.full((m, kcap), -np.inf, np.float32),
                np.full((m, kcap), -1, np.int64))
    return topk_mod.merge_row_partials(parts, kcap)


# ---------------------------------------------------------------------------
# Exact-geometry refinement on the CSR pool (paper §3.2.4), bucketed kernel
# ---------------------------------------------------------------------------

REFINE_MAX_PTS = 128        # size-class cap; larger geometries are fragmented


def _size_class(n: np.ndarray) -> np.ndarray:
    """Next power of two >= n (n in [1, REFINE_MAX_PTS])."""
    return (1 << np.ceil(np.log2(np.maximum(n, 1))).astype(np.int64)) \
        .astype(np.int64)


def core_to_dist(core: np.ndarray, metric: str) -> np.ndarray:
    """Metric *core* minima -> distances, in float64 numpy.

    The bucketed kernel reduces the metric core — squared euclid distance,
    or squared unit-sphere chord (= 4·haversine-h) — both monotone in the
    true distance, so the transform commutes with the min and runs once per
    pair here, in f64 numpy because XLA's jitted ``asin`` is not exact at 0
    (a self-distance would come back as ~3e-4 km).
    """
    core = np.asarray(core, dtype=np.float64)
    if metric == "haversine":
        return (2.0 * geometry.EARTH_RADIUS_KM
                * np.arcsin(np.clip(np.sqrt(core) * 0.5, 0.0, 1.0)))
    return np.sqrt(core)


def pool_min_dist(pool, rows_a: np.ndarray, rows_b: np.ndarray,
                  metric: str = "euclid", interpret: bool | None = None,
                  max_pts: int = REFINE_MAX_PTS) -> np.ndarray:
    """Exact min distance per (rows_a[t], rows_b[t]) geometry-pool row pair.

    Vectorized replacement for the per-pair python loop: pairs are grouped by
    padded (m_pad, n_pad) size class (next power of two per side), each
    bucket is gathered from the CSR pool's coordinate planes — raw x/y for
    euclid, unit-sphere X/Y/Z for haversine (chord² = 4h, trig hoisted to
    pool build) — into dense (B, m_pad) / (B, n_pad) tiles, padding
    replicating the entity's last point (which can never change a min), and
    one kernel call per bucket computes the pairwise minima
    (kernels/geom_refine.py; jnp oracle on CPU). Geometries wider than
    `max_pts` are fragmented into <= max_pts chunks on both sides (min
    distance decomposes over point subsets) and min-scattered back.
    Returns (n_pairs,) float64 distances (f32 cores, f64 final transform).
    """
    from ..kernels import ops as kops

    npairs = len(rows_a)
    out = np.full(npairs, np.inf, dtype=np.float32)
    if npairs == 0:
        return out
    rows_a = np.asarray(rows_a, dtype=np.int64)
    rows_b = np.asarray(rows_b, dtype=np.int64)
    off = pool.offsets
    cnt_a, cnt_b = pool.counts(rows_a), pool.counts(rows_b)
    na, nb = -(-cnt_a // max_pts), -(-cnt_b // max_pts)
    frags = na * nb
    if int(frags.max()) == 1:           # common case: no fragmentation
        pair_idx = np.arange(npairs, dtype=np.int64)
        start_a, len_a = off[rows_a], cnt_a
        start_b, len_b = off[rows_b], cnt_b
    else:
        pair_idx = np.repeat(np.arange(npairs, dtype=np.int64), frags)
        base = np.repeat(np.cumsum(frags) - frags, frags)
        local = np.arange(int(frags.sum()), dtype=np.int64) - base
        nb_r = nb[pair_idx]
        ca, cb = local // nb_r, local % nb_r
        start_a = off[rows_a][pair_idx] + ca * max_pts
        len_a = np.minimum(cnt_a[pair_idx] - ca * max_pts, max_pts)
        start_b = off[rows_b][pair_idx] + cb * max_pts
        len_b = np.minimum(cnt_b[pair_idx] - cb * max_pts, max_pts)
    cls_a, cls_b = _size_class(len_a), _size_class(len_b)
    planes = pool.planes3d() if metric == "haversine" else pool.planes2d()
    key = cls_a * (2 * max_pts) + cls_b
    for kk in np.unique(key):
        sel = np.flatnonzero(key == kk)
        m_pad, n_pad = int(cls_a[sel[0]]), int(cls_b[sel[0]])
        # pad the batch axis to a bounded shape family too: bucket sizes
        # are data-dependent, and unpadded they would jit-compile a fresh
        # kernel per distinct size. Rounding up at 3-significant-bit
        # granularity keeps <= 8 shapes per power of two and <= ~14% pad
        # waste. Padding replays the first fragment — min-scatter is
        # idempotent, so duplicates are harmless.
        blen = len(sel)
        g = 64 if blen <= 64 else 1 << max(6, blen.bit_length() - 3)
        bpad = -(-blen // g) * g
        sel = np.concatenate([sel, np.full(bpad - blen, sel[0],
                                           dtype=np.int64)])
        # clamped gather: index min(arange, len-1) replicates the last point
        ia = start_a[sel, None] + np.minimum(np.arange(m_pad)[None, :],
                                             (len_a[sel] - 1)[:, None])
        ib = start_b[sel, None] + np.minimum(np.arange(n_pad)[None, :],
                                             (len_b[sel] - 1)[:, None])
        c = np.asarray(kops.bucketed_min_core(
            tuple(p[ia] for p in planes), tuple(p[ib] for p in planes),
            interpret=interpret))
        np.minimum.at(out, pair_idx[sel], c)
    return core_to_dist(out, metric)


def refine(pairs_i: np.ndarray, pairs_j: np.ndarray,
           pool, rows_a: np.ndarray, rows_b: np.ndarray,
           dist_world: float, metric: str = "euclid",
           stats: JoinStats | None = None,
           interpret: bool | None = None) -> np.ndarray:
    """Exact-representation distance validation (paper §3.2.4), vectorized.

    rows_a / rows_b are geometry-pool rows per candidate pair (from
    ``store.geom_rows(ents[pairs_i])`` etc.). Returns a boolean keep mask;
    `refine_looped` is the per-pair oracle this must agree with.
    """
    d = pool_min_dist(pool, rows_a, rows_b, metric, interpret)
    # f64 compare: the threshold stays un-rounded (a f32-rounded threshold
    # could drop true survivors)
    keep = d <= float(dist_world)
    if stats is not None:
        stats.refined += int(keep.sum())
    return keep


def exact_pair_distance(pool, rows_a: np.ndarray, rows_b: np.ndarray,
                        metric: str = "euclid",
                        interpret: bool | None = None) -> np.ndarray:
    """Exact min distance per candidate pair, on the bucketed kernel path
    (shared by the engine's refinement and the baselines)."""
    return pool_min_dist(pool, rows_a, rows_b, metric, interpret)


def _pool_gather(pool, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(point indices, owning row segment) for the given pool rows."""
    from .squadtree import csr_gather  # lazy: avoid a module cycle
    rows = np.asarray(rows, dtype=np.int64)
    cnt = pool.counts(rows)
    idx = csr_gather(pool.offsets[rows], cnt)
    seg = np.repeat(np.arange(len(rows), dtype=np.int64), cnt)
    return idx, seg


def pool_points_in_box(pool, rows: np.ndarray, box) -> np.ndarray:
    """Per pool row: does any exact point lie inside the CLOSED world box?

    ``box`` is (xmin, ymin, xmax, ymax) in world units. The boundary counts
    (consistent with `geometry.boxes_intersect`), and a zero-area box still
    matches coincident points exactly. Exact — no MBR approximation.
    """
    rows = np.asarray(rows, dtype=np.int64)
    out = np.zeros(len(rows), dtype=bool)
    if len(rows) == 0:
        return out
    idx, seg = _pool_gather(pool, rows)
    x = pool.points[idx, 0].astype(np.float64)
    y = pool.points[idx, 1].astype(np.float64)
    xmin, ymin, xmax, ymax = (float(box[0]), float(box[1]),
                              float(box[2]), float(box[3]))
    inb = (x >= xmin) & (x <= xmax) & (y >= ymin) & (y <= ymax)
    np.logical_or.at(out, seg, inb)
    return out


def pool_point_min_dist(pool, rows: np.ndarray, point,
                        metric: str = "euclid") -> np.ndarray:
    """Exact min distance from each pool row's point set to a world point.

    f64 throughout (over the pool's f32 coordinates), so coincident points
    come back as exactly 0.0 — the within-distance selection shape and its
    brute-force oracle both score with this routine.
    """
    rows = np.asarray(rows, dtype=np.int64)
    out = np.full(len(rows), np.inf, dtype=np.float64)
    if len(rows) == 0:
        return out
    idx, seg = _pool_gather(pool, rows)
    pts = pool.points[idx].astype(np.float64)
    p = np.asarray(point, dtype=np.float64)
    dist_fn = (geometry.haversine_km if metric == "haversine"
               else geometry.euclid_dist)
    d = dist_fn(pts, p[None, :])
    np.minimum.at(out, seg, d)
    return out


def refine_looped(pairs_i: np.ndarray, pairs_j: np.ndarray,
                  driver_geom: list, driven_geom: list,
                  dist_world: float, metric: str = "euclid",
                  stats: JoinStats | None = None) -> np.ndarray:
    """Per-pair refinement oracle (the pre-pool python loop, kept as the
    specification for `refine` and the looped side of bench_refine.py).

    driver_geom / driven_geom are per-candidate exact geometries: (m, 2)
    point arrays (points, polylines, polygon rings). Returns a keep mask.
    """
    keep = np.zeros(len(pairs_i), dtype=bool)
    dist_fn = geometry.euclid_dist if metric == "euclid" else geometry.haversine_km
    for n in range(len(pairs_i)):
        pa = driver_geom[n]
        pb = driven_geom[n]
        d = dist_fn(pa[:, None, :], pb[None, :, :])
        keep[n] = bool((d <= dist_world).any())
    if stats is not None:
        stats.refined += int(keep.sum())
    return keep


def exact_pair_distance_looped(driver_geom: list, driven_geom: list,
                               metric: str = "euclid") -> np.ndarray:
    dist_fn = geometry.euclid_dist if metric == "euclid" else geometry.haversine_km
    out = np.empty(len(driver_geom))
    for n in range(len(driver_geom)):
        d = dist_fn(driver_geom[n][:, None, :], driven_geom[n][None, :, :])
        out[n] = float(d.min())
    return out
