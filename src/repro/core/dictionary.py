"""RDF term dictionary: strings <-> int64 ids, numeric literal values.

Spatial entities receive their (S, Z, I, L) ids from the S-QuadTree build;
everything else gets sequential non-spatial ids (S bit clear). Numeric
literals keep a side table id -> float used by ranking functions. Following
RDF-3X, the query engine never touches strings on the hot path.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dictionary:
    term_to_id: dict
    id_to_term: dict
    numeric_value: dict            # id -> float
    _next: int = 1                 # 0 reserved as NULL

    @staticmethod
    def empty() -> "Dictionary":
        return Dictionary({}, {}, {})

    def intern(self, term: str) -> int:
        i = self.term_to_id.get(term)
        if i is not None:
            return i
        i = self._next
        self._next += 1
        self.term_to_id[term] = i
        self.id_to_term[i] = term
        if _is_number(term):
            self.numeric_value[i] = float(term)
        return i

    def intern_numeric(self, value: float) -> int:
        return self.intern(repr(float(value)))

    def remap(self, mapping: dict) -> None:
        """Apply id remapping (plain id -> spatial id) after the tree build."""
        new_t2i, new_i2t, new_num = {}, {}, {}
        for t, i in self.term_to_id.items():
            j = mapping.get(i, i)
            new_t2i[t] = j
            new_i2t[j] = t
            if i in self.numeric_value:
                new_num[j] = self.numeric_value[i]
        self.term_to_id, self.id_to_term = new_t2i, new_i2t
        self.numeric_value = new_num

    def lookup(self, i: int) -> str:
        return self.id_to_term.get(int(i), f"_:id{int(i)}")

    def values_array(self, ids_arr: np.ndarray) -> np.ndarray:
        out = np.full(len(ids_arr), np.nan)
        for n, i in enumerate(np.asarray(ids_arr)):
            v = self.numeric_value.get(int(i))
            if v is not None:
                out[n] = v
        return out

    def __len__(self) -> int:
        return len(self.term_to_id)


def _is_number(term: str) -> bool:
    try:
        float(term)
        return True
    except ValueError:
        return False
