"""STR-packed R-tree + synchronous traversal distance join [Brinkhoff '93].

This is the comparison spatial-join algorithm from the paper's §5.2.1
(Sowell et al.'s iterated-join study): both inputs get an R-tree, the trees
are descended synchronously, and candidate pairs are emitted at the leaves.
It has neither identifier encoding, characteristic sets, nor SIP — exactly
the ablation STREAK is measured against (Fig. 8).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import geometry


@dataclasses.dataclass
class RTree:
    # level 0 = leaves; node_mbr stacked per level
    level_mbrs: list           # [ (n_l, 4) ] per level, level 0 first
    level_children: list       # [ (n_l,) offsets into level below ] CSR
    obj_index: np.ndarray      # leaf order -> original object row
    fanout: int

    @property
    def height(self) -> int:
        return len(self.level_mbrs)

    def nbytes(self) -> int:
        return sum(m.nbytes for m in self.level_mbrs) + self.obj_index.nbytes


def build_str(boxes: np.ndarray, fanout: int = 16) -> RTree:
    """Sort-Tile-Recursive bulk load."""
    boxes = np.asarray(boxes, dtype=np.float64)
    n = len(boxes)
    cx = (boxes[:, 0] + boxes[:, 2]) * 0.5
    cy = (boxes[:, 1] + boxes[:, 3]) * 0.5
    n_slices = max(1, int(np.ceil(np.sqrt(n / fanout))))
    order_x = np.argsort(cx, kind="stable")
    slice_size = int(np.ceil(n / n_slices))
    order = np.empty(n, dtype=np.int64)
    for s in range(n_slices):
        sl = order_x[s * slice_size:(s + 1) * slice_size]
        order[s * slice_size:s * slice_size + len(sl)] = sl[np.argsort(cy[sl],
                                                                       kind="stable")]
    leaf_boxes = boxes[order]
    level_mbrs = [leaf_boxes]
    level_children = [np.arange(n + 1, dtype=np.int64)]  # unused at leaves
    cur = leaf_boxes
    while len(cur) > 1:
        m = len(cur)
        n_parents = int(np.ceil(m / fanout))
        offs = np.minimum(np.arange(n_parents + 1, dtype=np.int64) * fanout, m)
        parent = np.empty((n_parents, 4))
        for p in range(n_parents):
            seg = cur[offs[p]:offs[p + 1]]
            parent[p] = geometry.union_boxes(seg)
        level_mbrs.append(parent)
        level_children.append(offs)
        cur = parent
    return RTree(level_mbrs, level_children, order, fanout)


@dataclasses.dataclass
class SyncJoinStats:
    node_pairs_visited: int = 0
    candidates: int = 0


def _expand(counts: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Concatenated aranges [starts[i], starts[i]+counts[i])."""
    nz = counts > 0
    s, c = starts[nz], counts[nz]
    total = int(c.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    out[0] = s[0]
    if len(s) > 1:
        pos = np.cumsum(c)[:-1]
        out[pos] = s[1:] - (s[:-1] + c[:-1] - 1)
    return np.cumsum(out)


def sync_distance_join(ta: RTree, tb: RTree, dist: float,
                       stats: SyncJoinStats | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Synchronous traversal: candidate object pairs within `dist`.

    All surviving node pairs sit at a common (level_a, level_b) so each
    expansion step is one vectorized MBR distance test. Returns
    (rows_a, rows_b) into the ORIGINAL box arrays.
    """
    stats = stats if stats is not None else SyncJoinStats()
    la, lb = ta.height - 1, tb.height - 1
    pa = np.zeros(1, dtype=np.int64)
    pb = np.zeros(1, dtype=np.int64)
    while True:
        d = geometry.box_min_dist(ta.level_mbrs[la][pa], tb.level_mbrs[lb][pb])
        keep = d <= dist
        stats.node_pairs_visited += len(pa)
        pa, pb = pa[keep], pb[keep]
        if len(pa) == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        if la == 0 and lb == 0:
            stats.candidates += len(pa)
            return ta.obj_index[pa], tb.obj_index[pb]
        if la >= lb and la > 0:  # descend the coarser side
            offs = ta.level_children[la]
            cnt = offs[pa + 1] - offs[pa]
            new_a = _expand(cnt, offs[pa])
            new_b = np.repeat(pb, cnt)
            pa, pb, la = new_a, new_b, la - 1
        else:
            offs = tb.level_children[lb]
            cnt = offs[pb + 1] - offs[pb]
            new_b = _expand(cnt, offs[pb])
            new_a = np.repeat(pa, cnt)
            pa, pb, lb = new_a, new_b, lb - 1
