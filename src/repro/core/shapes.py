"""Geographica-shaped query execution: range / within-distance / kNN /
non-top-k spatial join.

The paper's engine runs one query shape — the top-k distance join. The
standard geospatial-RDF benchmarks (Geographica / Geographica 2) mix four
more, and this module executes them on the SAME machinery the top-k cursor
uses: `plan_query` splits the sides, SIP Phases 1-2 run through
`shard.sip_select` (batched frontier or fused Pallas descent, per shard,
I-Range/E-list material), Phase 3 goes through `mbr_distance_join` (any
backend) and the bucketed exact-geometry kernel (`exact_pair_distance`),
and relational assembly reuses the merge-join core. Each shape has a
brute-force oracle in `core/baselines.py` (`FullScanEngine`) that must be
bit-identical — the differential fuzzer enforces this across backends and
shard counts.

Shape semantics (geometries are the exact point sets in the CSR pool):

- **range** — unary. A binding qualifies iff its entity's geometry has at
  least one point inside the CLOSED world window. Scores are all 0.0.
- **within** — unary. Qualifies iff min distance from the geometry to the
  world center point is <= ``dist``; the score is that distance.
- **knn** — binary, directional. Per driver (?a) entity, the ``knn``
  nearest distinct driven (?b) entities by exact min geometry distance
  (ties on distance break toward the smaller driven entity id). Fewer
  than k candidates ⟹ a SHORT list, never padding, never an error.
- **join** — binary, no ranking. Every (?a, ?b) entity pair with exact
  distance <= ``dist``; the score is the pair distance.

Selections return ALL qualifying rows (`Query.k` is ignored; Geographica
selections are not top-k), in a canonical deterministic order so engine
and oracle compare bit-identically: entity column(s) first, then the pair
distance, then the remaining columns lexicographically by name.
"""
from __future__ import annotations

import numpy as np

from . import shard as shard_mod, spatial_join
from .join import Relation, join
from .planner import QueryPlan, plan_query
from .query import Query

COVER_NORM = float(np.sqrt(2.0))    # normalized-space diameter bound


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _side_rel(engine, side, plan: QueryPlan) -> Relation:
    """Fully-joined relation of one side; a pattern-less side means "every
    spatial entity" (mirrors the FullScan oracle's convention)."""
    if not side.all_ordered:
        return Relation({side.entity_var:
                         np.unique(engine.store.tree.obj_ids)})
    return engine._driven_full(side, plan.join_impl, plan.rank_backend)


def _ents_boxes(store, rel: Relation, var: str
                ) -> tuple[np.ndarray, np.ndarray]:
    """Sorted unique entities of `rel[var]` that have geometry, plus their
    normalized MBRs."""
    if rel.n == 0 or var not in rel:
        return np.empty(0, np.int64), np.zeros((0, 4))
    ents = np.unique(rel[var])
    boxes = store.spatial_box_of(ents)
    ok = ~np.isnan(boxes[:, 0])
    return ents[ok], boxes[ok]


class _Sip:
    """Per-query SIP state (Phases 1-2 across shard views), reusable across
    driver chunks / kNN rounds — the same prepared Bloom keys, root-path
    masks, and per-shard CS cardinalities the top-k cursor precomputes."""

    def __init__(self, engine, plan: QueryPlan):
        cfg = engine.config
        self.engine = engine
        self.plan = plan
        self.enabled = bool(cfg.use_sip) and engine.store.tree is not None
        self.shards = (shard_mod.shard_views(engine.store) if self.enabled
                       else shard_mod.whole_view(engine.store))
        if not self.enabled:
            return
        tree = engine.store.tree
        self.prepared = tree.bloom_self.prepare(plan.driven_cs)
        self.card_all = [sh.tree.cs_stats.cardinality_all(plan.driven_cs)
                         for sh in self.shards]
        self.cs_path = (
            [sh.tree.cs_path_mask(plan.driven_cs, prepared=self.prepared,
                                  probe_backend=plan.probe_backend)
             for sh in self.shards]
            if plan.descend_backend != "numpy" else None)

    def filter(self, box_sets: list, dist_norm: float, ents: np.ndarray,
               stats) -> list[np.ndarray]:
        """One batched Phases-1-2 call over `box_sets` (one entry per driver
        chunk), then per-chunk boolean masks over the sorted unique entity
        array `ents` — an entity survives a chunk iff ANY shard's I-Range /
        E-list material covers it (shard materials partition the id space,
        so the union is exact)."""
        if not self.enabled:
            return [np.ones(len(ents), dtype=bool) for _ in box_sets]
        plan, cfg = self.plan, self.engine.config
        v_stars = shard_mod.sip_select(
            self.shards, box_sets, dist_norm, plan.driven_cs, self.prepared,
            plan.probe_backend, plan.descend_backend, self.cs_path,
            cfg.select_params, self.card_all)
        masks = []
        for v_star in v_stars:
            stats.v_star_sizes.append(sum(len(v) for v in v_star))
            keep = np.zeros(len(ents), dtype=bool)
            for si, sh in enumerate(self.shards):
                if len(v_star[si]) == 0:
                    continue
                intervals, explicit = sh.filter_material(v_star[si])
                keep |= _material_mask(ents, intervals, explicit)
            masks.append(keep)
        return masks


def _material_mask(ents: np.ndarray, intervals: np.ndarray,
                   explicit: np.ndarray) -> np.ndarray:
    """SIP membership of sorted ids in I-Range intervals / E-list ids —
    the array-side twin of `join.filter_in_ranges`."""
    keep = np.zeros(len(ents), dtype=bool)
    if len(ents) == 0:
        return keep
    if len(intervals):
        iv = intervals[np.argsort(intervals[:, 0])]
        starts = iv[:, 0]
        ends = np.maximum.accumulate(iv[:, 1])
        pos = np.searchsorted(starts, ents, "right") - 1
        ok = pos >= 0
        keep[ok] = ents[ok] <= ends[np.clip(pos[ok], 0, len(ends) - 1)]
    if len(explicit):
        pos = np.clip(np.searchsorted(explicit, ents), 0, len(explicit) - 1)
        keep |= explicit[pos] == ents
    return keep


def _canonical_order(rows: Relation, primary: list[str],
                     scores: np.ndarray | None = None) -> np.ndarray:
    """Deterministic output permutation: `primary` columns (major first),
    then the score, then every remaining column by name."""
    keys: list[np.ndarray] = []
    for c in primary:
        if c in rows:
            keys.append(rows[c])
    if scores is not None:
        keys.append(scores)
    for c in sorted(rows.keys()):
        if c not in primary:
            keys.append(rows[c])
    if not keys or len(keys[0]) == 0:
        return np.empty(0, dtype=np.int64)
    return np.lexsort(tuple(reversed(keys)))


def _pair_scores(rows: Relation, a_var: str, b_var: str,
                 pa: np.ndarray, pb: np.ndarray,
                 d: np.ndarray) -> np.ndarray:
    """Per-row distance lookup: (pa, pb, d) lists unique qualifying entity
    pairs; every (a_var, b_var) value pair in `rows` is one of them.

    Entity ids are dictionary hashes (~2^62), so keying on raw
    ``a * span + b`` would wrap int64 and collide; compress both columns
    to dense ranks first."""
    if rows.n == 0:
        return np.empty(0, dtype=np.float64)
    ua, ub = np.unique(pa), np.unique(pb)
    span = np.int64(len(ub) + 1)
    key = np.searchsorted(ua, pa) * span + np.searchsorted(ub, pb)
    order = np.argsort(key)
    rk = (np.searchsorted(ua, rows[a_var]) * span
          + np.searchsorted(ub, rows[b_var]))
    pos = np.searchsorted(key[order], rk)
    return d[order[np.clip(pos, 0, len(order) - 1)]]


def _assemble_pairs(plan: QueryPlan, drv_rel: Relation,
                    dvn_rel: Relation, a_ents: np.ndarray,
                    b_ents: np.ndarray, d: np.ndarray
                    ) -> tuple[np.ndarray, Relation]:
    """Join qualifying (a, b) entity pairs back through both sides' full
    relations and order canonically with per-row pair distances. Shared
    with the FullScan oracles: output assembly is plumbing, the candidate
    generation it consumes is what the differential tests exercise."""
    a_var = plan.driver.entity_var
    b_var = plan.driven.entity_var
    pair_rel = Relation({a_var: a_ents, b_var: b_ents})
    out = join(drv_rel, pair_rel, impl=plan.join_impl,
               backend=plan.rank_backend)
    out = join(out, dvn_rel, impl=plan.join_impl, backend=plan.rank_backend)
    scores = _pair_scores(out, a_var, b_var, a_ents, b_ents, d)
    order = _canonical_order(out, [a_var], scores)
    return scores[order], out.take(order)


def _chunks(n: int, size: int) -> list[np.ndarray]:
    size = max(int(size), 1)
    return [np.arange(s, min(s + size, n), dtype=np.int64)
            for s in range(0, n, size)] or []


# ---------------------------------------------------------------------------
# shape executors
# ---------------------------------------------------------------------------

def execute_shape(engine, q: Query, deadline=None):
    """Execute a non-top-k shape on a `StreakEngine`. Returns
    (scores, rows, ExecStats) with the canonical deterministic ordering."""
    from .executor import ExecStats   # lazy: executor imports this module
    cfg = engine.config
    plan = plan_query(engine.store, q, force_driver=cfg.force_driver,
                      policy=cfg.policy)
    stats = ExecStats()
    if plan.shape == "range":
        scores, rows = _exec_range(engine, q, plan, stats)
    elif plan.shape == "within":
        scores, rows = _exec_within(engine, q, plan, stats)
    elif plan.shape == "knn":
        scores, rows = _exec_knn(engine, q, plan, stats, deadline)
    elif plan.shape == "join":
        scores, rows = _exec_join(engine, q, plan, stats, deadline)
    else:
        raise ValueError(f"not a shape query: {plan.shape!r}")
    return scores, rows, stats


def _select_rows(rel: Relation, var: str, keep_ents: np.ndarray,
                 ent_scores: np.ndarray) -> tuple[np.ndarray, Relation]:
    """Filter a selection's relation to qualifying entities and order
    canonically; per-row scores follow the entity's score."""
    if rel.n == 0 or len(keep_ents) == 0:
        empty = rel.take(np.empty(0, dtype=np.int64))
        return np.empty(0, dtype=np.float64), empty
    pos = np.searchsorted(keep_ents, rel[var])
    ok = (pos < len(keep_ents)) & \
        (keep_ents[np.clip(pos, 0, len(keep_ents) - 1)] == rel[var])
    out = rel.take(np.flatnonzero(ok))
    scores = ent_scores[np.clip(pos[ok], 0, len(keep_ents) - 1)]
    order = _canonical_order(out, [var], scores)
    return scores[order], out.take(order)


def _exec_range(engine, q: Query, plan: QueryPlan, stats):
    store = engine.store
    rel = _side_rel(engine, plan.driver, plan)
    stats.driven_rows_scanned += rel.n
    ents, boxes = _ents_boxes(store, rel, plan.driver.entity_var)
    win = np.asarray(q.spatial.window, dtype=np.float64)
    ext = store.tree.extent
    win_norm = ext.normalize(win[None, :])[0]
    sip = _Sip(engine, plan)
    stats.driver_blocks += 1
    stats.plan_s += 1
    stats.plan_log.append("S")
    keep = sip.filter([win_norm[None, :]], 0.0, ents, stats)[0]
    # MBR prefilter in normalized space (conservative), exact point-in-
    # window test on the pool only for survivors
    from .geometry import boxes_intersect
    keep &= boxes_intersect(boxes, win_norm[None, :])
    stats.driven_rows_after_sip += int(keep.sum())
    cand = np.flatnonzero(keep)
    hit = spatial_join.pool_points_in_box(
        store.geom_pool, store.geom_rows(ents[cand]), win)
    qual = ents[cand[hit]]
    scores, rows = _select_rows(rel, plan.driver.entity_var, qual,
                                np.zeros(len(qual)))
    stats.results_considered += rows.n
    return scores, rows


def _exec_within(engine, q: Query, plan: QueryPlan, stats):
    store = engine.store
    rel = _side_rel(engine, plan.driver, plan)
    stats.driven_rows_scanned += rel.n
    ents, boxes = _ents_boxes(store, rel, plan.driver.entity_var)
    ext = store.tree.extent
    c = np.asarray(q.spatial.center, dtype=np.float64)
    c_box = ext.normalize(np.array([[c[0], c[1], c[0], c[1]]]))
    sip = _Sip(engine, plan)
    stats.driver_blocks += 1
    stats.plan_s += 1
    stats.plan_log.append("S")
    keep = sip.filter([c_box], plan.dist_norm, ents, stats)[0]
    from .geometry import box_min_dist
    keep &= box_min_dist(boxes, c_box[0][None, :]) <= plan.dist_norm
    stats.driven_rows_after_sip += int(keep.sum())
    cand = np.flatnonzero(keep)
    d = spatial_join.pool_point_min_dist(
        store.geom_pool, store.geom_rows(ents[cand]), c, plan.metric)
    ok = d <= float(plan.dist_world)
    qual, dq = ents[cand[ok]], d[ok]
    scores, rows = _select_rows(rel, plan.driver.entity_var, qual, dq)
    stats.results_considered += rows.n
    return scores, rows


def _exec_join(engine, q: Query, plan: QueryPlan, stats, deadline=None):
    store = engine.store
    cfg = engine.config
    drv_rel = _side_rel(engine, plan.driver, plan)
    dvn_rel = _side_rel(engine, plan.driven, plan)
    stats.driven_rows_scanned += dvn_rel.n
    a_ents, a_boxes = _ents_boxes(store, drv_rel, plan.driver.entity_var)
    b_ents, b_boxes = _ents_boxes(store, dvn_rel, plan.driven.entity_var)
    rows_a_all = store.geom_rows(a_ents)
    rows_b_all = store.geom_rows(b_ents)
    sip = _Sip(engine, plan)
    chunks = _chunks(len(a_ents), cfg.block)
    pa, pb, pd = [], [], []
    if chunks and len(b_ents):
        masks = sip.filter([a_boxes[c] for c in chunks], plan.dist_norm,
                           b_ents, stats)
        for c, keep in zip(chunks, masks):
            if deadline is not None \
                    and deadline.expired(stats.driver_blocks):
                stats.deadline_expired = True
                stats.partial = True
                break
            stats.driver_blocks += 1
            stats.plan_s += 1
            stats.plan_log.append("S")
            cand = np.flatnonzero(keep)
            stats.driven_rows_after_sip += len(cand)
            if len(cand) == 0:
                continue
            pi, pj = spatial_join.mbr_distance_join(
                a_boxes[c], b_boxes[cand], plan.dist_norm,
                plan.join_backend, stats.join)
            if len(pi) == 0:
                continue
            gi, gj = c[pi], cand[pj]
            d = spatial_join.exact_pair_distance(
                store.geom_pool, rows_a_all[gi], rows_b_all[gj],
                plan.metric)
            ok = d <= float(plan.dist_world)
            stats.join.refined += int(ok.sum())
            pa.append(gi[ok])
            pb.append(gj[ok])
            pd.append(d[ok])
    if pa:
        ia = np.concatenate(pa)
        ib = np.concatenate(pb)
        dd = np.concatenate(pd)
    else:
        ia = ib = np.empty(0, dtype=np.int64)
        dd = np.empty(0, dtype=np.float64)
    scores, rows = _assemble_pairs(plan, drv_rel, dvn_rel,
                                   a_ents[ia], b_ents[ib], dd)
    stats.results_considered += rows.n
    return scores, rows


def _exec_knn(engine, q: Query, plan: QueryPlan, stats, deadline=None):
    """Per-driver-entity k nearest driven entities, by certified radius
    doubling: a round's MBR join at world radius r finds EVERY pair with
    exact distance <= r (the conservative anisotropic normalization rule,
    see `Extent.denormalize_distance`), so a driver whose k-th nearest
    found candidate lies within r is final. Radii grow geometrically until
    the normalized radius covers the unit square (COVER_NORM), at which
    point the candidate set is complete and every remaining driver —
    including those with fewer than k reachable candidates — certifies
    with a possibly SHORT list."""
    store = engine.store
    k = int(q.spatial.knn)
    if k <= 0:
        raise ValueError(f"knn must be positive, got {k}")
    drv_rel = _side_rel(engine, plan.driver, plan)
    dvn_rel = _side_rel(engine, plan.driven, plan)
    stats.driven_rows_scanned += dvn_rel.n
    a_ents, a_boxes = _ents_boxes(store, drv_rel, plan.driver.entity_var)
    b_ents, b_boxes = _ents_boxes(store, dvn_rel, plan.driven.entity_var)
    rows_a_all = store.geom_rows(a_ents)
    rows_b_all = store.geom_rows(b_ents)
    sip = _Sip(engine, plan)
    ext = store.tree.extent

    res_a: list[np.ndarray] = []
    res_b: list[np.ndarray] = []
    res_d: list[np.ndarray] = []
    unc = np.arange(len(a_ents), dtype=np.int64)
    if len(b_ents) == 0:
        unc = unc[:0]       # nothing reachable: every driver is (empty) done
    r = float(q.spatial.dist) if q.spatial.dist > 0 \
        else min(ext.width, ext.height) / 1024.0
    while len(unc):
        rn = ext.denormalize_distance(r)
        final = rn >= COVER_NORM
        if deadline is not None and deadline.expired(stats.driver_blocks):
            stats.deadline_expired = True
            stats.partial = True
            break
        stats.driver_blocks += 1
        stats.plan_s += 1
        stats.plan_log.append("S")
        keep = sip.filter([a_boxes[unc]], rn, b_ents, stats)[0]
        cand = np.flatnonzero(keep)
        stats.driven_rows_after_sip += len(cand)
        done_rounds = np.zeros(len(unc), dtype=bool)
        if len(cand):
            pi, pj = spatial_join.mbr_distance_join(
                a_boxes[unc], b_boxes[cand], rn, plan.join_backend,
                stats.join)
            if len(pi):
                gi = unc[pi]                 # global driver index
                gj = cand[pj]                # global driven index
                d = spatial_join.exact_pair_distance(
                    store.geom_pool, rows_a_all[gi], rows_b_all[gj],
                    plan.metric)
                within = d <= r
                # per-driver certified-candidate counts (complete up to r)
                cnt = np.zeros(len(unc), dtype=np.int64)
                np.add.at(cnt, pi, within.astype(np.int64))
                done_rounds = cnt >= k
                if final:
                    done_rounds[:] = True
                take_pair = done_rounds[pi] & (within | final)
                if take_pair.any():
                    ti = pi[take_pair]
                    td = d[take_pair]
                    tj = gj[take_pair]
                    # k smallest per driver by (distance, driven entity)
                    order = np.lexsort((b_ents[tj], td, ti))
                    ti, td, tj = ti[order], td[order], tj[order]
                    first = np.r_[True, ti[1:] != ti[:-1]]
                    grp = np.flatnonzero(first)
                    width = np.diff(np.r_[grp, len(ti)])
                    rank = (np.arange(len(ti), dtype=np.int64)
                            - np.repeat(grp, width))
                    sel = rank < k
                    res_a.append(unc[ti[sel]])
                    res_b.append(tj[sel])
                    res_d.append(td[sel])
        elif final:
            done_rounds = np.ones(len(unc), dtype=bool)
        if final:
            break
        unc = unc[~done_rounds]
        r *= 4.0
    ia = np.concatenate(res_a) if res_a else np.empty(0, np.int64)
    ib = np.concatenate(res_b) if res_b else np.empty(0, np.int64)
    dd = np.concatenate(res_d) if res_d else np.empty(0, np.float64)
    scores, rows = _assemble_pairs(plan, drv_rel, dvn_rel,
                                   a_ents[ia], b_ents[ib], dd)
    stats.results_considered += rows.n
    return scores, rows


# ---------------------------------------------------------------------------
# serve-mode adapter
# ---------------------------------------------------------------------------

class ShapeCursor:
    """Cursor-protocol adapter so the multi-tenant serving loop can admit
    non-top-k shapes: the whole shape executes inside the slot's first
    `begin_block()` (crash-isolated by the serve loop like any per-slot
    phase) and the call returns None, which retires the slot with the
    results. `step()` supports the serial `execute()` protocol too."""

    def __init__(self, engine, q: Query, deadline=None):
        from .executor import ExecStats
        self.engine = engine
        self.q = q
        self.deadline = deadline
        self.done = False
        self.stats = ExecStats()
        self._scores = np.empty(0, dtype=np.float64)
        self._rows = Relation()

    def _run(self) -> None:
        if not self.done:
            self._scores, self._rows, self.stats = execute_shape(
                self.engine, self.q, deadline=self.deadline)
            self.done = True

    def step(self) -> None:
        self._run()

    def begin_block(self):
        self._run()
        return None

    def finish_block(self, v_stars=None, batcher=None) -> None:
        raise AssertionError("ShapeCursor.begin_block always returns None")

    def results(self):
        return self._scores, self._rows, self.stats
