"""Fig. 8: candidate pairs, S-QuadTree join vs synchronous R-tree traversal
— plus the fused-vs-matrix Phase-3 kernel comparison.

The paper's key index ablation: same block pipeline, the spatial join
swapped. We report MBR-level candidate counts (lower = better pruning) and
end-to-end time. The `fused_join/` section measures the streaming top-k
kernel against the matrix+mask path across M, N, k: both compute the same
global top-k pair set, but the fused path consumes the evolving θ between
column batches (early termination inside the join) and never materializes
the (M, N) matrix — its peak intermediate bytes are independent of N.
"""
from __future__ import annotations

import numpy as np

from repro.core import spatial_join
from repro.core.baselines import SyncRTreeEngine
from repro.core.executor import ExecConfig, StreakEngine
from repro.core.join import Relation
from repro.core.topk import TopK
from repro.kernels import ops as kops

from . import common

FUSED_BATCH = 2048


def _rand_boxes(rng, n: int, side: float = 0.01) -> np.ndarray:
    pts = rng.random((n, 2))
    wh = rng.random((n, 2)) * side
    return np.concatenate([pts, pts + wh], axis=1)


def fused_vs_matrix() -> list:
    """Same task both ways: global top-k in-distance pairs by score bound."""
    rows = []
    rng = np.random.default_rng(0)
    for m, n in ((2048, 2048), (8192, 2048), (8192, 8192)):
        a, b = _rand_boxes(rng, m), _rand_boxes(rng, n)
        a32, b32 = a.astype(np.float32), b.astype(np.float32)
        dk, vk = rng.random(m), rng.random(n)
        dist = 0.05
        for k in (16, 64):
            def run_matrix():
                mask = np.asarray(kops.distance_join_mask(a32, b32, dist))
                i, j = np.nonzero(mask)
                s = dk[i] + vk[j]
                if len(s) > k:
                    s = s[np.argpartition(-s, k - 1)[:k]]
                return np.sort(s)[::-1]

            def run_fused():
                tk = TopK(k=k)
                for pi, pj in spatial_join.fused_stream_join(
                        a, b, dk, vk, dist, k=k,
                        theta_fn=lambda: tk.theta, batch_cols=FUSED_BATCH):
                    tk.push(dk[pi] + vk[pj], Relation({"i": pi, "j": pj}))
                return tk.results()[0]

            # both paths must agree before being timed
            np.testing.assert_allclose(run_matrix(), run_fused(), rtol=1e-6)
            t_mat = common.timeit(run_matrix)
            t_fus = common.timeit(run_fused)
            peak_mat = m * n * 5          # f32 matrix + bool mask
            peak_fus = m * FUSED_BATCH * 4 + m * k * 8
            rows.append(common.row(
                f"fused_join/m{m}_n{n}_k{k}_matrix", t_mat,
                f"peak_bytes={peak_mat}"))
            rows.append(common.row(
                f"fused_join/m{m}_n{n}_k{k}_fused", t_fus,
                f"peak_bytes={peak_fus};speedup={t_mat / t_fus:.2f}x"))
    return rows


def engine_backends() -> list:
    """End-to-end engine time per Phase-3 backend on one dataset/query."""
    rows = []
    ds = common.dataset("lgd")
    q = ds.queries[0]
    for backend in ("numpy", "kernel", "fused"):
        eng = StreakEngine(ds.store, ExecConfig(join_backend=backend))
        eng.execute(q)  # warm caches / jit
        t = common.timeit(lambda: eng.execute(q))
        rows.append(common.row(f"fig8_join/backend_{backend}", t, ""))
    return rows


def run() -> list:
    rows = fused_vs_matrix()
    rows += engine_backends()
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            squad = StreakEngine(ds.store, ExecConfig(force_plan="S"))
            rtree = SyncRTreeEngine(ds.store)
            _, _, st_q = squad.execute(q)
            _, _, st_r = rtree.execute(q)
            t_q = common.timeit(lambda: squad.execute(q))
            t_r = common.timeit(lambda: rtree.execute(q))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_squadtree", t_q,
                f"cands={st_q.join.candidates}"))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_sync_rtree", t_r,
                f"cands={st_r.join.candidates};"
                f"ratio={st_r.join.candidates/max(st_q.join.candidates,1):.1f}x"))
    return rows
