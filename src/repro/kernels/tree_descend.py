"""Pallas TPU kernel: fused S-QuadTree candidate-node descent (Phase 1).

`squadtree.candidate_nodes` used to walk the tree one level at a time —
every level a host round-trip (np.unique over the frontier, Bloom probes,
MBR tests, child pushes). The MBR nesting invariant collapses the whole
traversal: a child's MBR is contained in its parent's *exactly* in f64
(each node's MBR is the min/max union of object boxes clipped to its cell,
over a subset of the parent's objects clipped to a nested cell), so an
expanded driver box that hits a node's MBR hits every ancestor's too, and
the level-synchronous frontier's verdict for node n under block b reduces
to

    in_v[b, n] = any_box_hit(b, n) & cs_path[n]

where cs_path ANDs the Bloom verdict down the root path — block- and
box-independent, precomputed once per query (`SQuadTree.cs_path_mask`).
What remains for the device is a dense (block, node) interval test over
all boxes: embarrassingly parallel, zero per-level host syncs.

The engine's box tests are f64 ``<=`` comparisons and the kernel runs
32-bit math, so coordinates are mapped on the host to order-isomorphic
int64 sort keys (`ops.f64_sort_keys`: IEEE-754 total-order flip, -0.0
canonicalized) and split into (hi32, sign-flipped lo32) planes; the
lexicographic plane compare below equals the f64 compare bit-for-bit —
the same plane trick the merge-join rank kernel uses for its int64 keys.

Grid: (blocks, node tiles, box tiles); each (1, nt) node-tile output row
is an accumulator revisited across the box-tile axis (zeroed on the first
tile via `pl.when`), OR-ing in each box tile's hit-any reduction, so one
(bm-box, nt-node) tile pair is VMEM resident at a time. Node lanes padded
past N carry cs = 0; box rows padded past M carry the never-intersecting
sentinel box (mins at the key maximum, maxs at the key minimum — real
keys live strictly inside the int64 range, see `ops.f64_sort_keys`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plane_le(a_hi, a_lo, b_hi, b_lo):
    """Broadcasted a <= b on (hi32, sign-flipped lo32) int64 key planes."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def _kernel(nx0h_ref, nx0l_ref, ny0h_ref, ny0l_ref,
            nx2h_ref, nx2l_ref, ny3h_ref, ny3l_ref,
            bx0h_ref, bx0l_ref, by0h_ref, by0l_ref,
            bx2h_ref, bx2l_ref, by3h_ref, by3l_ref,
            cs_ref, out_ref):
    # the (1, nt) node-tile row is an accumulator revisited across the
    # box-tile axis (out index map ignores program_id(2))
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # interval test: node MBR (a) vs expanded driver box (b) intersect iff
    # a.x0 <= b.x2 & b.x0 <= a.x2 & a.y0 <= b.y3 & b.y0 <= a.y3
    hit = (_plane_le(nx0h_ref[...], nx0l_ref[...],      # (1, nt) node planes
                     bx2h_ref[...], bx2l_ref[...])      # (bm, 1) box planes
           & _plane_le(bx0h_ref[...], bx0l_ref[...],
                       nx2h_ref[...], nx2l_ref[...])
           & _plane_le(ny0h_ref[...], ny0l_ref[...],
                       by3h_ref[...], by3l_ref[...])
           & _plane_le(by0h_ref[...], by0l_ref[...],
                       ny3h_ref[...], ny3l_ref[...]))   # (bm, nt)
    any_hit = jnp.max(hit.astype(jnp.int32), axis=0, keepdims=True)
    out_ref[...] = out_ref[...] | (any_hit & cs_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "nt", "interpret"))
def tree_descend(nodes_hi: jnp.ndarray, nodes_lo: jnp.ndarray,
                 cs: jnp.ndarray, boxes_hi: jnp.ndarray,
                 boxes_lo: jnp.ndarray, bm: int = 512, nt: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """Dense candidate-node masks over one driver-block batch.

    nodes_* (4, N) int32 key planes of the node MBRs (rows x0, y0, x2, y3);
    cs (N,) int32 0/1 root-path Bloom mask; boxes_* (B, M, 4) planes of the
    expanded driver boxes, padding rows pre-sentineled by the caller
    (`ops.DESCEND_PAD_BOX`). `bm` / `nt` bound the VMEM-resident box / node
    tiles (`nt` lane-rounded and clamped to the padded node count).
    Returns (B, N) int32 0/1 masks.
    """
    b, m = boxes_hi.shape[0], boxes_hi.shape[1]
    n = nodes_hi.shape[1]
    nt = max(-(-nt // 128) * 128, 128)
    n128 = max(-(-n // 128) * 128, 128)
    nt = min(nt, n128)
    n_pad = -(-n128 // nt) * nt
    bm = max(bm, 8)
    m_pad = max(-(-m // bm) * bm, bm)
    # node-lane padding: zero keys, killed by cs = 0
    nodes_hi = jnp.pad(nodes_hi, ((0, 0), (0, n_pad - n)))
    nodes_lo = jnp.pad(nodes_lo, ((0, 0), (0, n_pad - n)))
    cs = jnp.pad(cs, (0, n_pad - n)).reshape(1, -1)
    if m_pad > m:  # box-row padding: the never-intersecting sentinel box
        sent = jnp.array([[0x7FFFFFFF, 0x7FFFFFFF,
                           -0x80000000, -0x80000000]], jnp.int32)
        pad = jnp.broadcast_to(sent, (b, m_pad - m, 4))
        boxes_hi = jnp.concatenate([boxes_hi, pad], axis=1)
        boxes_lo = jnp.concatenate([boxes_lo, pad], axis=1)
    bh = boxes_hi.reshape(-1, 4)    # (B * m_pad, 4)
    bl = boxes_lo.reshape(-1, 4)
    mt = m_pad // bm
    node_spec = pl.BlockSpec((1, nt), lambda bb, t, j: (0, t))
    box_spec = pl.BlockSpec((bm, 1), lambda bb, t, j: (bb * mt + j, 0))
    node_in = [p[c:c + 1, :] for c in range(4) for p in (nodes_hi, nodes_lo)]
    box_in = [p[:, c:c + 1] for c in range(4) for p in (bh, bl)]
    out = pl.pallas_call(
        _kernel,
        grid=(b, n_pad // nt, mt),
        in_specs=[node_spec] * 8 + [box_spec] * 8 + [node_spec],
        out_specs=pl.BlockSpec((1, nt), lambda bb, t, j: (bb, t)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.int32),
        interpret=interpret,
    )(*node_in, *box_in, cs)
    return out[:, :n]
