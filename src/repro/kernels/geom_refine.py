"""Pallas TPU kernel: bucketed exact-geometry min-distance (refinement §3.2.4).

Refinement validates MBR candidate pairs against exact point-set geometries
(points / polylines / polygon rings). The CSR geometry pool (core/store.py)
lets the caller gather a whole *bucket* of candidate pairs — all padded to
one (m_pad, n_pad) size class — into dense per-dimension coordinate planes:

    a_planes  dims x (B, m_pad)   driver points, one plane per coordinate so
    b_planes  dims x (B, n_pad)   the lane dimension is a point axis

Both metrics reduce to the same kernel: euclidean refinement uses the raw
(x, y) planes (dims=2), haversine uses per-point unit-sphere (X, Y, Z)
planes (dims=3, ``GeomPool.planes3d``) whose squared chord distance is
``4·h`` — so the inner loop is pure multiply/add either way, with the trig
hoisted to pool build time and the monotone final transform
(core/spatial_join.py::core_to_dist) applied once per pair in float64.

Padding replicates a real point of the same entity (every pool row holds at
least one point), so duplicated points can never change the minimum and the
kernel needs no validity masks. Per block row the kernel walks the m_pad
driver points with a fori_loop, broadcasting each against all n_pad driven
points on the VPU, and keeps the running minimum of the squared distance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

POS_INF = float("inf")


def _kernel(*refs, m_pad: int, dims: int):
    a = [r[...] for r in refs[:dims]]               # dims x (bb, m_pad)
    b = [r[...] for r in refs[dims:2 * dims]]       # dims x (bb, n_pad)
    out_ref = refs[2 * dims]

    def body(i, best):
        v = None
        for ad, bd in zip(a, b):
            ai = jax.lax.dynamic_slice_in_dim(ad, i, 1, axis=1)  # (bb, 1)
            d = ai - bd
            v = d * d if v is None else v + d * d
        return jnp.minimum(best, jnp.min(v, axis=1, keepdims=True))

    init = jnp.full(out_ref.shape, POS_INF, dtype=out_ref.dtype)
    out_ref[...] = jax.lax.fori_loop(0, m_pad, body, init)


@jax.jit
def bucketed_min_core_host(a_planes: tuple, b_planes: tuple) -> jnp.ndarray:
    """CPU twin of the kernel: same fori_loop over driver points, (B, n_pad)
    working set. ~2-4x faster on CPU than jitting the dense (B, m, n) oracle
    (XLA CPU materializes the cube), with the kernel's exact numerics."""
    m_pad = a_planes[0].shape[1]

    def body(i, best):
        v = None
        for ad, bd in zip(a_planes, b_planes):
            ai = jax.lax.dynamic_slice_in_dim(ad, i, 1, axis=1)
            d = ai - bd
            v = d * d if v is None else v + d * d
        return jnp.minimum(best, jnp.min(v, axis=1))

    init = jnp.full(a_planes[0].shape[0], POS_INF, dtype=jnp.float32)
    return jax.lax.fori_loop(0, m_pad, body, init)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def bucketed_min_core(a_planes: tuple, b_planes: tuple,
                      bb: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Per-pair min squared distance over one padded size-class bucket.

    a_planes / b_planes: dims-tuples of (B, m_pad) / (B, n_pad) float32
    coordinate planes (padding must replicate real points). Returns (B,)
    float32 minima of ``sum_d (a_d - b_d)²`` over the m_pad x n_pad point
    pairs of each row; the caller applies the metric's monotone distance
    transform.
    """
    dims = len(a_planes)
    m, m_pad = a_planes[0].shape
    n_pad = b_planes[0].shape[1]
    bp = -(-m // bb) * bb
    tiles = [jnp.pad(t.astype(jnp.float32), ((0, bp - m), (0, 0)))
             for t in (*a_planes, *b_planes)]
    raw = pl.pallas_call(
        functools.partial(_kernel, m_pad=m_pad, dims=dims),
        grid=(bp // bb,),
        in_specs=([pl.BlockSpec((bb, m_pad), lambda i: (i, 0))] * dims
                  + [pl.BlockSpec((bb, n_pad), lambda i: (i, 0))] * dims),
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(*tiles)
    return raw[:m, 0]
