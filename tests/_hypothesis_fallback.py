"""Minimal random-sampling stand-in for `hypothesis`.

The property tests only need a small strategy surface (integers, lists,
tuples, composite, data). When the real `hypothesis` package is installed
(CI installs it from requirements-dev.txt) this module is never imported;
without it, tests/conftest.py registers this module under the `hypothesis`
name so the suite still collects and the properties are checked against
`max_examples` random samples (no shrinking, no database — a smoke-grade
substitute, not a replacement).
"""
from __future__ import annotations

import inspect
import random
import types
import zlib


class Strategy:
    """A sampler: strategy.sample(rng) -> value."""

    def __init__(self, sample_fn, name="strategy"):
        self._sample_fn = sample_fn
        self._name = name

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)

    def example(self):
        return self.sample(random.Random(0))

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<fallback {self._name}>"


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value), "integers")


def floats(min_value=0.0, max_value=1.0, **_):
    return Strategy(lambda rng: rng.uniform(min_value, max_value), "floats")


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5, "booleans")


def sampled_from(elements):
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randrange(len(elements))],
                    "sampled_from")


def lists(elements: Strategy, min_size=0, max_size=10):
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]
    return Strategy(sample, "lists")


def tuples(*strats: Strategy):
    return Strategy(lambda rng: tuple(s.sample(rng) for s in strats),
                    "tuples")


class _DataObject:
    """Interactive draws inside a test body (st.data())."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy, label=None):
        return strategy.sample(self._rng)


def data():
    return Strategy(lambda rng: _DataObject(rng), "data")


def composite(fn):
    """@st.composite def s(draw, ...): ... -> callable returning a Strategy."""
    def builder(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.sample(rng), *args, **kwargs)
        return Strategy(sample, f"composite:{fn.__name__}")
    builder.__name__ = fn.__name__
    return builder


def settings(**kwargs):
    """Records max_examples on the function; other knobs are ignored."""
    def deco(fn):
        fn._fallback_settings = dict(kwargs)
        return fn
    return deco


def given(*strats: Strategy, **kwstrats: Strategy):
    """Run the test `max_examples` times with freshly sampled arguments.

    The wrapper exposes a zero-parameter signature so pytest does not
    mistake strategy-supplied arguments for fixtures.
    """
    def deco(fn):
        def wrapper():
            cfg = getattr(fn, "_fallback_settings", None) or \
                getattr(wrapper, "_fallback_settings", None) or {}
            n = int(cfg.get("max_examples", 100))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                args = [s.sample(rng) for s in strats]
                kwargs = {k: s.sample(rng) for k, s in kwstrats.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature([])
        return wrapper
    return deco


def install() -> types.ModuleType:
    """Build module objects mimicking `hypothesis` / `hypothesis.strategies`."""
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "data", "composite"):
        setattr(st_mod, name, globals()[name])
    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__fallback__ = True
    return hyp_mod
