"""Training loop: jit'd step + checkpoint/restart + straggler guard.

Works for every family (the step fn and batch iterator come from the cell
builders / data pipeline). Used by examples/train_lm.py and the fault
-tolerance integration tests.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from ..dist import grad_compression
from . import checkpoint as ckpt_lib
from . import fault, optim


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    step_deadline_s: float = 0.0      # 0 = no straggler guard
    max_restarts: int = 3
    log_every: int = 10
    compress_grads: bool = False      # int8 EF compression (cross-pod hook)


class Trainer:
    def __init__(self, loss_fn, params, cfg: TrainerConfig,
                 opt_cfg: optim.AdamWConfig | None = None,
                 donate: bool = True):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or optim.AdamWConfig()
        self.params = params
        self.opt_state = optim.init_state(params)
        self.err_state = (grad_compression.init_error_state(params)
                          if cfg.compress_grads else None)
        self.step = 0
        self.ckpt = ckpt_lib.Checkpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.history: list = []
        loss_grad = jax.value_and_grad(loss_fn)
        compress = cfg.compress_grads

        def _step(params, opt_state, err_state, batch):
            loss, grads = loss_grad(params, *batch)
            if compress:
                grads, err_state = \
                    grad_compression.tree_ef_compress_roundtrip(grads,
                                                                err_state)
            params, opt_state, metrics = optim.apply_updates(
                params, grads, opt_state, self.opt_cfg)
            return params, opt_state, err_state, loss, metrics

        self._jit_step = jax.jit(_step, donate_argnums=(0, 1)
                                 if donate else ())

    # ------------------------------------------------------------------
    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": self.step}

    def save(self, blocking: bool = False):
        self.ckpt.save(self.step, self.state_tree(), blocking=blocking)

    def maybe_restore(self) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        state, step = self.ckpt.restore(self.state_tree())
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
        return True

    # ------------------------------------------------------------------
    def fit(self, batches, n_steps: int,
            injector: fault.FailureInjector | None = None):
        """Run up to n_steps over `batches` (callable step->batch)."""
        cfg = self.cfg

        def body():
            while self.step < n_steps:
                batch = batches(self.step)
                if injector is not None:
                    injector.check(self.step)
                t0 = time.time()
                if cfg.step_deadline_s > 0:
                    with fault.StepGuard(cfg.step_deadline_s):
                        out = self._call(batch)
                else:
                    out = self._call(batch)
                loss = out
                self.step += 1
                self.history.append(float(loss))
                if self.step % cfg.log_every == 0:
                    dt = time.time() - t0
                    print(f"step {self.step}: loss {float(loss):.4f} "
                          f"({dt*1e3:.0f} ms/step)")
                if self.step % cfg.ckpt_every == 0:
                    self.save()
            self.save(blocking=True)
            return self.history

        def restore():
            self.ckpt.wait()
            self.maybe_restore()

        return fault.run_with_recovery(
            body, restore, max_restarts=cfg.max_restarts,
            on_restart=lambda n, e: print(f"[recovery #{n}] {e}; resuming "
                                          f"from step {self.step}"))

    def _call(self, batch):
        self.params, self.opt_state, self.err_state, loss, _ = \
            self._jit_step(self.params, self.opt_state, self.err_state,
                           batch)
        return loss
