"""Refinement-path tests: CSR geometry pool + bucketed min-distance kernel.

The per-pair python loop (`spatial_join.refine_looped` /
`exact_pair_distance_looped`, float64) is the specification; the bucketed
kernel path must reproduce its keep masks exactly on randomized geometries
for both metrics, across size classes, fragmentation, single-point
geometries, MBR-corner fallback entities, and empty pair sets.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import spatial_join
from repro.core.store import GeomPool, build_store
from repro.core.dictionary import Dictionary


def _rand_pool(rng, n_entities: int, max_pts: int = 9,
               lonlat: bool = False) -> GeomPool:
    counts = rng.integers(1, max_pts + 1, size=n_entities)
    pts = []
    for c in counts:
        if lonlat:
            p = np.stack([rng.uniform(-179, 179, c),
                          rng.uniform(-85, 85, c)], axis=-1)
        else:
            p = rng.uniform(0, 100, size=(c, 2))
        pts.append(p)
    return GeomPool.from_lists(pts)


def _slices(pool: GeomPool, rows: np.ndarray) -> list:
    off = pool.offsets
    return [np.asarray(pool.points[off[r]:off[r + 1]], dtype=np.float64)
            for r in rows]


def _assert_matches_looped(pool, ra, rb, metric, **kw):
    """Bucketed distances ~= looped f64, keep masks bit-identical at
    thresholds placed between well-separated adjacent distances."""
    n = len(ra)
    got = spatial_join.pool_min_dist(pool, ra, rb, metric, **kw)
    want = spatial_join.exact_pair_distance_looped(
        _slices(pool, ra), _slices(pool, rb), metric)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    uniq = np.unique(want)
    mids = (uniq[:-1] + uniq[1:]) / 2.0
    safe = mids[np.diff(uniq) > 1e-3 * (1.0 + mids)]
    pairs = np.arange(n)
    for dist in safe[:: max(len(safe) // 3, 1)]:
        keep = spatial_join.refine(pairs, pairs, pool, ra, rb,
                                   float(dist), metric)
        keep_loop = spatial_join.refine_looped(
            pairs, pairs, _slices(pool, ra), _slices(pool, rb),
            float(dist), metric)
        np.testing.assert_array_equal(keep, keep_loop)


@given(st.integers(1, 60), st.integers(1, 40), st.integers(0, 10 ** 6),
       st.booleans())
@settings(max_examples=15, deadline=None)
def test_bucketed_refine_matches_looped_oracle(n_pairs, n_entities, seed,
                                               haversine):
    metric = "haversine" if haversine else "euclid"
    rng = np.random.default_rng(seed)
    pool = _rand_pool(rng, n_entities, lonlat=haversine)
    ra = rng.integers(0, n_entities, n_pairs).astype(np.int64)
    rb = rng.integers(0, n_entities, n_pairs).astype(np.int64)
    _assert_matches_looped(pool, ra, rb, metric)


def test_single_point_geometries():
    """All-1-point pool: min distance is the plain point distance."""
    rng = np.random.default_rng(3)
    pool = _rand_pool(rng, 50, max_pts=1)
    ra = rng.integers(0, 50, 200).astype(np.int64)
    rb = rng.integers(0, 50, 200).astype(np.int64)
    got = spatial_join.pool_min_dist(pool, ra, rb, "euclid")
    pa = pool.points[pool.offsets[ra]].astype(np.float64)
    pb = pool.points[pool.offsets[rb]].astype(np.float64)
    want = np.sqrt(((pa - pb) ** 2).sum(axis=1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fragmentation_of_wide_geometries():
    """Geometries wider than max_pts are chunked on both sides and the
    fragment minima scatter back to the true pair minimum."""
    rng = np.random.default_rng(4)
    pool = GeomPool.from_lists([rng.uniform(0, 100, size=(m, 2))
                                for m in (300, 7, 130, 1, 64)])
    ra = np.array([0, 0, 2, 4, 3], dtype=np.int64)
    rb = np.array([1, 2, 0, 0, 3], dtype=np.int64)
    for max_pts in (16, 128):          # multi-fragment and default paths
        _assert_matches_looped(pool, ra, rb, "euclid", max_pts=max_pts)


def test_empty_pair_set():
    rng = np.random.default_rng(5)
    pool = _rand_pool(rng, 4)
    empty = np.empty(0, dtype=np.int64)
    assert spatial_join.pool_min_dist(pool, empty, empty, "euclid").shape == (0,)
    keep = spatial_join.refine(empty, empty, pool, empty, empty, 1.0, "euclid")
    assert keep.shape == (0,) and keep.dtype == bool


def _tiny_store(with_exact_for=("a",)):
    """Two-entity store; entities outside `with_exact_for` fall back to
    MBR-corner pool entries."""
    d = Dictionary.empty()
    T = d.intern
    has_geom = T("hasGeometry")
    quads, geoms, exact = [], {}, {}
    world = {"a": (10.0, 10.0, 12.0, 14.0), "b": (30.0, 40.0, 33.0, 41.0)}
    for name, box in world.items():
        e = T(name)
        quads.append((0, e, has_geom, T(f"geo:{name}")))
        geoms[e] = box
        if name in with_exact_for:
            rng = np.random.default_rng(len(name))
            exact[e] = np.stack([rng.uniform(box[0], box[2], 5),
                                 rng.uniform(box[1], box[3], 5)], axis=-1)
    store = build_store(np.array(quads, dtype=np.int64), d,
                        geometry_predicate=has_geom, geometries=geoms,
                        exact_geoms=exact, block=16, l_max=4)
    ids = {n: store.dictionary.term_to_id[n] for n in world}
    return store, ids, world


def test_mbr_corner_fallback_entities():
    """Entities without ingested exact geometry get their denormalized MBR
    corners as the pool entry — same fallback the pre-pool code used."""
    store, ids, world = _tiny_store(with_exact_for=("a",))
    ea = np.array([ids["a"], ids["b"]], dtype=np.int64)
    rows = store.geom_rows(ea)
    cnts = store.geom_pool.counts(rows)
    assert cnts[0] == 5 and cnts[1] == 2              # exact vs corner pair
    (ga, gb) = store.exact_geometry(ea)
    np.testing.assert_allclose(gb[0], world["b"][:2], atol=1e-4)
    np.testing.assert_allclose(gb[1], world["b"][2:], atol=1e-4)
    # refinement over a fallback entity matches the looped oracle
    ra = store.geom_rows(np.array([ids["a"]]))
    rb = store.geom_rows(np.array([ids["b"]]))
    d = spatial_join.pool_min_dist(store.geom_pool, ra, rb, "euclid")
    want = spatial_join.exact_pair_distance_looped([ga], [gb], "euclid")
    np.testing.assert_allclose(d, want, rtol=1e-5)


def test_unknown_entity_maps_to_sentinel():
    store, ids, _ = _tiny_store()
    rows = store.geom_rows(np.array([ids["a"], 10 ** 9], dtype=np.int64))
    assert rows[1] == store.geom_pool.sentinel_row
    geo = store.exact_geometry(np.array([10 ** 9], dtype=np.int64))
    np.testing.assert_array_equal(geo[0], np.zeros((1, 2)))


def test_exact_geometry_is_pool_view():
    """The compatibility view must read back exactly the pool's points."""
    store, ids, _ = _tiny_store(with_exact_for=("a", "b"))
    ea = np.array([ids["a"], ids["b"]], dtype=np.int64)
    rows = store.geom_rows(ea)
    off = store.geom_pool.offsets
    for g, r in zip(store.exact_geometry(ea), rows):
        np.testing.assert_array_equal(
            g, store.geom_pool.points[off[r]:off[r + 1]].astype(np.float64))
