"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]: 24L d_model=2048 16H
(kv=16) per-expert d_ff=1408 vocab=151936, MoE 60 routed top-4 + 4 shared.
Experts padded 60 -> 64 for the 16-way "model" axis (router masks padding)."""
from ..models.moe import MoEConfig
from .registry import LM_SHAPES as SHAPES  # noqa: F401

FAMILY = "moe"
CONFIG = MoEConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, head_dim=128, vocab=151936,
    n_experts=60, n_experts_padded=64, top_k=4, d_ff_expert=1408,
    n_shared=4, act="silu", norm="rms", rope_theta=1e6,
    dtype="bfloat16", remat=True, loss_chunks=16)
SMOKE = MoEConfig(
    name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=2,
    n_kv_heads=2, head_dim=32, vocab=256, n_experts=6, n_experts_padded=8,
    top_k=4, d_ff_expert=48, n_shared=2, act="silu", norm="rms",
    dtype="float32", remat=False)
