"""Async, atomic, resumable checkpointing (no orbax in this container).

Layout:
    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step
        arrays.npz           # flattened leaves (addressable shards gathered)
    <dir>/LATEST             # atomic pointer file (rename-into-place)

Guarantees:
- atomicity: writes go to step_XXX.tmp-<pid>, fsync'd, then renamed;
  LATEST is updated last, so a crash mid-write never corrupts resume state;
- async: `save()` snapshots to host memory synchronously (cheap) and does
  the serialization on a daemon thread; `wait()` joins before the next save;
- retention: keep the newest `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]   # device->host snapshot
        structure = jax.tree_util.tree_structure(tree)

        def _write():
            tmp = self.dir / f"step_{step:09d}.tmp-{os.getpid()}"
            final = self.dir / f"step_{step:09d}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / "arrays.npz",
                     **{f"leaf_{i}": a for i, a in enumerate(host)})
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "treedef": str(structure),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            latest_tmp = self.dir / f".LATEST.tmp-{os.getpid()}"
            latest_tmp.write_text(final.name)
            os.replace(latest_tmp, self.dir / "LATEST")
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and ".tmp" not in p.name)
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        name = latest.read_text().strip()
        if not (self.dir / name / "manifest.json").exists():
            return None
        return int(name.split("_")[1])

    def restore(self, tree_like):
        """Restore into the structure of `tree_like` (device placement and
        sharding follow the example tree when it holds jax arrays)."""
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = self.dir / f"step_{step:09d}"
        data = np.load(path / "arrays.npz")
        leaves, treedef = _flatten(tree_like)
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        out = []
        for ref, arr in zip(leaves, restored):
            if hasattr(ref, "sharding") and hasattr(ref, "dtype"):
                out.append(jax.device_put(arr.astype(ref.dtype), ref.sharding))
            else:
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step
