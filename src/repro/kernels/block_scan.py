"""Pallas TPU kernel: blocked top-k summary scan (threshold machinery).

One HBM pass over score blocks producing, per block: the block maximum (the
upper bound the APS cost model and early termination compare against theta),
the survivor count, and the survivor mask. Fusing the three avoids three
separate elementwise passes over the candidate scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, theta_ref, max_ref, cnt_ref, mask_ref):
    s = scores_ref[...]                        # (1, B)
    theta = theta_ref[0, 0]
    m = s > theta
    max_ref[...] = jnp.max(s, axis=1, keepdims=True)
    cnt_ref[...] = jnp.sum(m.astype(jnp.int32), axis=1, keepdims=True)
    mask_ref[...] = m.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_scan(scores: jnp.ndarray, theta: float,
               interpret: bool = False):
    """scores (nb, B) float32 -> (block_max (nb,), count (nb,), mask (nb,B))."""
    nb, bsz = scores.shape
    theta_arr = jnp.full((1, 1), theta, dtype=jnp.float32)
    bmax, cnt, mask = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bsz), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, bsz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb, bsz), jnp.uint8),
        ],
        interpret=interpret,
    )(scores.astype(jnp.float32), theta_arr)
    return bmax[:, 0], cnt[:, 0], mask
