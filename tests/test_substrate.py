"""Distribution substrate tests: checkpoint, fault tolerance, compression,
elastic resharding, data pipeline, retrieval primitives, serving engine."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import graphs, recsys, tokens
from repro.dist import elastic, grad_compression
from repro.models import transformer
from repro.serve import retrieval
from repro.train import checkpoint, fault, loop, optim


# ------------------------------------------------------------- checkpoint ---
def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = checkpoint.Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}, "step": 7}
    for s in (1, 2, 3):
        ck.save(s, tree, blocking=True)
    assert ck.latest_step() == 3
    restored, step = ck.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    # retention: only 2 newest kept
    dirs = [p.name for p in ck.dir.iterdir() if p.name.startswith("step_")]
    assert len(dirs) == 2


def test_checkpoint_atomic_latest(tmp_path):
    ck = checkpoint.Checkpointer(tmp_path, keep=3)
    ck.save(5, {"x": jnp.zeros(3)}, blocking=True)
    (tmp_path / "step_000000006").mkdir()  # crash artifact without manifest
    (tmp_path / "LATEST").write_text("step_000000006")
    assert ck.latest_step() is None  # refuses corrupt pointer


# ------------------------------------------------- trainer + fault inject ---
def _tiny_cfg():
    return transformer.TransformerConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=64, dtype="float32", remat=False, loss_chunks=1)


def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = _tiny_cfg()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    stream = tokens.TokenStream(cfg.vocab, 16, 8, seed=1)

    def loss_fn(p, batch):
        return transformer.lm_loss(p, batch, cfg)

    tcfg = loop.TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                              log_every=100)
    # schedule sized to the 30-step smoke run (the default 100-step warmup
    # would leave the lr near zero for the whole test)
    ocfg = optim.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=60,
                             weight_decay=0.0)
    tr = loop.Trainer(loss_fn, params, tcfg, opt_cfg=ocfg)
    hist = tr.fit(lambda s: (jnp.asarray(stream.batch(s)),), n_steps=30)
    assert np.mean(hist[:5]) > np.mean(hist[-5:])  # it learns
    # resume from checkpoint: a new trainer continues at saved step
    tr2 = loop.Trainer(loss_fn, params, tcfg, opt_cfg=ocfg)
    assert tr2.maybe_restore()
    assert tr2.step == 30


def test_trainer_recovers_from_injected_failure(tmp_path):
    cfg = _tiny_cfg()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    stream = tokens.TokenStream(cfg.vocab, 16, 8, seed=2)

    def loss_fn(p, batch):
        return transformer.lm_loss(p, batch, cfg)

    tcfg = loop.TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                              log_every=1000, max_restarts=2)
    tr = loop.Trainer(loss_fn, params, tcfg)
    inj = fault.FailureInjector(fail_at_steps=(12,))
    hist = tr.fit(lambda s: (jnp.asarray(stream.batch(s)),), n_steps=20,
                  injector=inj)
    assert tr.step == 20  # finished despite the failure at step 12


def test_step_guard_detects_straggler():
    import time
    with pytest.raises(fault.StragglerTimeout):
        with fault.StepGuard(0.05):
            time.sleep(0.2)


# ------------------------------------------------------- grad compression ---
def test_ef_compression_bias_vanishes_over_steps():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    acc_true = np.zeros(256)
    acc_comp = np.zeros(256)
    for _ in range(50):
        codes, scale, err = grad_compression.ef_compress(g, err)
        acc_true += np.asarray(g)
        acc_comp += np.asarray(grad_compression.decompress(codes, scale))
    # accumulated compressed sum tracks the true sum (error feedback)
    rel = np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02


def test_compression_is_4x_smaller():
    g = jnp.ones((1024,), jnp.float32)
    codes, scale = grad_compression.compress(g)
    assert codes.dtype == jnp.int8 and codes.nbytes * 4 == g.nbytes


# ------------------------------------------------------------- elastic ------
def test_elastic_shrink_and_reshard():
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >= 2 devices")
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=n, model=1)
    x = jax.device_put(jnp.arange(n * 4.0).reshape(n, 4),
                       NamedSharding(mesh, P("data", None)))
    new_mesh = elastic.shrink_mesh(mesh, n_lost=1, model_axis="model")
    assert new_mesh.devices.size <= n - 1 or n == 1
    y = elastic.reshard_tree({"x": x}, {"x": x.sharding}, new_mesh)
    np.testing.assert_array_equal(np.asarray(y["x"]), np.asarray(x))


def test_elastic_respec_folds_pod_axis():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    new_mesh = Mesh(dev, ("data", "model"))
    old_mesh = Mesh(dev.reshape(1, 1, 1), ("pod", "data", "model"))
    s = NamedSharding(old_mesh, P(("pod", "data"), None))
    ns = elastic.respec(s, new_mesh)
    assert ns.spec == P(("data",), None)


# ------------------------------------------------------------ data pipes ----
def test_token_stream_deterministic_and_sharded():
    a = tokens.TokenStream(100, 8, 4, seed=3, process_index=0,
                           process_count=2)
    b = tokens.TokenStream(100, 8, 4, seed=3, process_index=1,
                           process_count=2)
    x0 = a.batch(0)
    assert x0.shape == (2, 9)
    np.testing.assert_array_equal(x0, a.batch(0))  # deterministic
    assert not np.array_equal(x0, b.batch(0))      # different shard


def test_neighbor_sampler_shapes_and_locality():
    edges = graphs.random_power_law_graph(500, 6, seed=1)
    feats = np.random.default_rng(0).normal(size=(500, 8)).astype(np.float32)
    labels = np.zeros(500, dtype=np.int32)
    samp = graphs.NeighborSampler(edges, 500, feats, labels, (5, 3), seed=0)
    seeds = np.arange(16)
    blk = samp.sample(seeds)
    assert blk.edges.shape == (2, 16 * 5 + 16 * 5 * 3)
    assert blk.mask.sum() == 16
    n_local = (blk.nodes >= 0).sum()
    assert blk.edges.max() < max(n_local, 1)


def test_spatial_graph_matches_bruteforce():
    rng = np.random.default_rng(2)
    pos = rng.normal(size=(80, 3)) * 3
    edges = graphs.spatial_graph(pos, cutoff=2.0)
    d = np.sqrt(((pos[:, None] - pos[None]) ** 2).sum(-1))
    expect = {(i, j) for i, j in zip(*np.nonzero(d <= 2.0)) if i != j}
    # spatial_graph prunes on the xy-plane first then refines in 3d: every
    # returned edge must be a true edge, and all true edges must be found
    got = set(zip(edges[0].tolist(), edges[1].tolist()))
    assert got == expect


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20.0).reshape(10, 2))
    idx = jnp.asarray([0, 1, 2, 5])
    off = jnp.asarray([0, 2])   # bags: [0,1], [2,5]
    s = recsys.embedding_bag(table, idx, off, "sum")
    np.testing.assert_allclose(np.asarray(s),
                               [[0 + 2, 1 + 3], [4 + 10, 5 + 11]])
    m = recsys.embedding_bag(table, idx, off, "mean")
    np.testing.assert_allclose(np.asarray(m), [[1, 2], [7, 8]])


# ---------------------------------------------------------- retrieval -------
def test_blocked_topk_matches_dense():
    rng = np.random.default_rng(4)
    state = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    items = jnp.asarray(rng.normal(size=(1000, 16)).astype(np.float32))
    scores, ids = retrieval.blocked_topk(state, items, k=10, block=128)
    dense = np.asarray(state @ items.T)
    for b in range(3):
        want = np.sort(dense[b])[::-1][:10]
        np.testing.assert_allclose(np.sort(np.asarray(scores[b]))[::-1],
                                   want, rtol=1e-5)


def test_streak_topk_exact_and_early_terminates():
    rng = np.random.default_rng(5)
    state = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    items = jnp.asarray((rng.normal(size=(2000, 16))
                         * rng.exponential(1.0, size=(2000, 1)))
                        .astype(np.float32))
    block = 128
    items_sorted, order = retrieval.sort_items_by_norm(items, block)
    bounds = retrieval.block_bounds(items_sorted, block)
    scores, ids, blocks_read = retrieval.streak_topk(
        state, items_sorted, order.astype(jnp.int32), bounds,
        k=10, block=block)
    dense = np.asarray(state @ items.T)
    for b in range(2):
        want = np.sort(dense[b])[::-1][:10]
        np.testing.assert_allclose(np.sort(np.asarray(scores[b]))[::-1],
                                   want, rtol=1e-5)
        got_ids = set(np.asarray(ids[b]).tolist())
        want_ids = set(np.argsort(-dense[b])[:10].tolist())
        assert got_ids == want_ids
    nb = -(-2000 // block)
    assert int(blocks_read) < nb  # the paper's early-out actually fired


# ---------------------------------------------------------- serve engine ----
def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = _tiny_cfg()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(transformer, params, cfg, max_batch=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    # decode path consistency vs full forward: compare in logit space with a
    # tolerance instead of requiring argmax equality — under concurrent CPU
    # load XLA may partition reductions differently between the decode and
    # forward paths, and near-tied logits can flip the argmax (known flake)
    h = transformer.forward(params, jnp.asarray([[1, 2, 3]]), cfg)
    lg = np.asarray(transformer.logits_fn(params, h, cfg)[0, -1],
                    dtype=np.float64)
    assert lg[reqs[0].out[0]] >= lg.max() - 1e-4 * max(1.0, abs(lg.max()))
