"""Z-order (Morton) curve encoding.

The S-QuadTree imposes *equivalent hierarchies* for the quadtree and the
Z-curve (paper §3.1.1): the Z-order of a node at level ``l`` is the ``2l``-bit
prefix of the Morton codes of everything below it. We keep two implementations:
a numpy one for index construction and a jnp one for the jitted query path
(plus a Pallas kernel in ``repro.kernels.morton_kernel``).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

_B = [
    0x5555555555555555,
    0x3333333333333333,
    0x0F0F0F0F0F0F0F0F,
    0x00FF00FF00FF00FF,
    0x0000FFFF0000FFFF,
]


def _part1by1_np(x: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of x so there is a 0 between each bit."""
    x = x.astype(np.uint64) & np.uint64(0xFFFFFFFF)
    x = (x | (x << np.uint64(16))) & np.uint64(_B[4])
    x = (x | (x << np.uint64(8))) & np.uint64(_B[3])
    x = (x | (x << np.uint64(4))) & np.uint64(_B[2])
    x = (x | (x << np.uint64(2))) & np.uint64(_B[1])
    x = (x | (x << np.uint64(1))) & np.uint64(_B[0])
    return x


def _compact1by1_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(_B[0])
    x = (x | (x >> np.uint64(1))) & np.uint64(_B[1])
    x = (x | (x >> np.uint64(2))) & np.uint64(_B[2])
    x = (x | (x >> np.uint64(4))) & np.uint64(_B[3])
    x = (x | (x >> np.uint64(8))) & np.uint64(_B[4])
    x = (x | (x >> np.uint64(16))) & np.uint64(0xFFFFFFFF)
    return x


def interleave2(cx: np.ndarray, cy: np.ndarray) -> np.ndarray:
    """Morton code with x in even bits, y in odd bits (numpy, uint64)."""
    return _part1by1_np(np.asarray(cx)) | (_part1by1_np(np.asarray(cy)) << np.uint64(1))


def deinterleave2(z: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    z = np.asarray(z, dtype=np.uint64)
    return _compact1by1_np(z), _compact1by1_np(z >> np.uint64(1))


def cell_of(xy: np.ndarray, level: int) -> np.ndarray:
    """Integer cell coordinates of normalized points at a quadtree level."""
    n = 1 << level
    c = np.floor(np.asarray(xy, dtype=np.float64) * n).astype(np.int64)
    return np.clip(c, 0, n - 1)


def encode_points(xy: np.ndarray, level: int) -> np.ndarray:
    """Morton codes (2*level bits) of normalized points, numpy int64."""
    c = cell_of(xy, level)
    return interleave2(c[:, 0], c[:, 1]).astype(np.int64)


def common_level(z_lo: np.ndarray, z_hi: np.ndarray, level: int) -> np.ndarray:
    """Deepest level at which two Morton codes (at `level`) share a node.

    This is how an object's (Z, L) is derived: take the codes of the MBR's
    low/high corners at the max level; the deepest fully-enclosing node is
    their common Z-prefix (paper §3.1.1).
    """
    x = (np.asarray(z_lo) ^ np.asarray(z_hi)).astype(np.uint64)
    nbits = np.zeros(x.shape, dtype=np.int64)
    v = x.copy()
    for _ in range(2 * level):  # bit-length, vectorized
        nz = v != 0
        nbits += nz.astype(np.int64)
        v >>= np.uint64(1)
    # ceil(nbits / 2) quad-levels are lost to the differing suffix
    return level - ((nbits + 1) // 2)


def zpath_at(z: np.ndarray, from_level: int, to_level: int) -> np.ndarray:
    """Truncate a Morton code from `from_level` to its `to_level` prefix."""
    return np.asarray(z) >> np.int64(2 * (from_level - to_level))


# ----------------------------------------------------------------------------
# jnp twins
# ----------------------------------------------------------------------------

def _part1by1_jnp(x):
    x = x.astype(jnp.uint32)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def jnp_interleave2(cx, cy):
    """Morton code for 16-bit cell coords (covers level <= 16), jnp int32."""
    return (_part1by1_jnp(cx) | (_part1by1_jnp(cy) << 1)).astype(jnp.int32)


def jnp_encode_points(xy, level: int):
    n = 1 << level
    c = jnp.clip(jnp.floor(xy * n).astype(jnp.int32), 0, n - 1)
    return jnp_interleave2(c[..., 0], c[..., 1])
