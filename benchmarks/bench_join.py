"""Fig. 8: candidate pairs, S-QuadTree join vs synchronous R-tree traversal
— plus the fused-vs-matrix Phase-3 kernel comparison.

The paper's key index ablation: same block pipeline, the spatial join
swapped. We report MBR-level candidate counts (lower = better pruning) and
end-to-end time. The `fused_join/` section measures the streaming top-k
kernel against the matrix+mask path across M, N, k: both compute the same
global top-k pair set, but the fused path consumes the evolving θ between
column batches (early termination inside the join) and never materializes
the (M, N) matrix — its peak intermediate bytes are independent of N.

The `merge_join/` section is the relational-path microbench: the two-phase
rank/gather merge join (`join`, arithmetic composite-key packing + one
dispatched rank pass + CSR gather) against the pre-rework numpy path
(`join_looped`: lexsort + per-column np.unique dense ranking + range
expansion), on duplicate-keyed relations — plus the same comparison for
`semijoin`, `filter_in_ranges`, and the end-to-end engine `join_impl` knob.
Both paths must produce bit-identical relations before being timed.
"""
from __future__ import annotations

import numpy as np

from repro import ExecConfig, Relation, StreakEngine
from repro.core import spatial_join
from repro.core.baselines import SyncRTreeEngine
from repro.core.join import (filter_in_ranges,
                             filter_in_ranges_looped, join, join_looped,
                             semijoin, semijoin_looped)
from repro.core.topk import TopK
from repro.kernels import ops as kops

from . import common

FUSED_BATCH = 2048


def _rand_boxes(rng, n: int, side: float = 0.01) -> np.ndarray:
    pts = rng.random((n, 2))
    wh = rng.random((n, 2)) * side
    return np.concatenate([pts, pts + wh], axis=1)


def fused_vs_matrix() -> list:
    """Same task both ways: global top-k in-distance pairs by score bound."""
    rows = []
    rng = np.random.default_rng(0)
    for m, n in ((2048, 2048), (8192, 2048), (8192, 8192)):
        a, b = _rand_boxes(rng, m), _rand_boxes(rng, n)
        a32, b32 = a.astype(np.float32), b.astype(np.float32)
        dk, vk = rng.random(m), rng.random(n)
        dist = 0.05
        for k in (16, 64):
            def run_matrix():
                mask = np.asarray(kops.distance_join_mask(a32, b32, dist))
                i, j = np.nonzero(mask)
                s = dk[i] + vk[j]
                if len(s) > k:
                    s = s[np.argpartition(-s, k - 1)[:k]]
                return np.sort(s)[::-1]

            def run_fused():
                tk = TopK(k=k)
                for pi, pj in spatial_join.fused_stream_join(
                        a, b, dk, vk, dist, k=k,
                        theta_fn=lambda: tk.theta, batch_cols=FUSED_BATCH):
                    tk.push(dk[pi] + vk[pj], Relation({"i": pi, "j": pj}))
                return tk.results()[0]

            # both paths must agree before being timed
            np.testing.assert_allclose(run_matrix(), run_fused(), rtol=1e-6)
            t_mat = common.timeit(run_matrix)
            t_fus = common.timeit(run_fused)
            peak_mat = m * n * 5          # f32 matrix + bool mask
            peak_fus = m * FUSED_BATCH * 4 + m * k * 8
            rows.append(common.row(
                f"fused_join/m{m}_n{n}_k{k}_matrix", t_mat,
                f"peak_bytes={peak_mat}"))
            rows.append(common.row(
                f"fused_join/m{m}_n{n}_k{k}_fused", t_fus,
                f"peak_bytes={peak_fus};speedup={t_mat / t_fus:.2f}x"))
    return rows


def _assert_rel_identical(x: Relation, y: Relation) -> None:
    assert set(x) == set(y)
    for c in x:
        np.testing.assert_array_equal(x[c], y[c])


def merge_join_micro() -> list:
    """Two-phase merge join vs the pre-rework numpy looped path.

    Two key-multiplicity regimes: `dup` (domain = n/4, ~4x fan-out per key —
    output materialization, paid by both paths, dominates) and `sel`
    (domain = 4n, selective — the join machinery itself dominates, where the
    packing/rank core replaces the looped path's per-column unique sorts).
    """
    rows = []
    rng = np.random.default_rng(7)
    for n, n_cols, regime in ((2048, 1, "dup"), (8192, 1, "dup"),
                              (8192, 1, "sel"), (8192, 2, "dup"),
                              (8192, 2, "sel"), (32768, 2, "sel"),
                              (65536, 2, "sel")):
        dom = n // 4 if regime == "dup" else 4 * n
        names = ("x", "y")[:n_cols]

        def rel(extra):
            r = Relation({c: rng.integers(0, dom, n).astype(np.int64)
                          for c in names})
            r[extra] = rng.integers(0, 1 << 20, n).astype(np.int64)
            return r

        a, b = rel("va"), rel("vb")
        out_l, out_m = join_looped(a, b), join(a, b)
        _assert_rel_identical(out_l, out_m)

        def cold_join():
            # repeat joins over the same relations replay cached packed keys
            # (see Relation._keycache); drop them so this row stays the
            # cold-path measurement it always was
            a.__dict__.pop("_keycache", None)
            b.__dict__.pop("_keycache", None)
            return join(a, b)

        t_l = common.timeit(lambda: join_looped(a, b))
        t_m = common.timeit(cold_join)
        tag = f"n{n}_c{n_cols}_{regime}"
        rows.append(common.row(f"merge_join/{tag}_looped", t_l,
                               f"out_rows={out_l.n}"))
        rows.append(common.row(f"merge_join/{tag}_merge", t_m,
                               f"out_rows={out_m.n};speedup={t_l/t_m:.2f}x"))
        if n >= 32768:
            # warm-cache replay: the `_join_chain` steady state, where the
            # pack + argsort of the reused side are skipped entirely
            join(a, b)                    # populate both sides' pack caches
            t_w = common.timeit(lambda: join(a, b))
            rows.append(common.row(
                f"merge_join/{tag}_merge_warm", t_w,
                f"out_rows={out_m.n};speedup_vs_cold={t_m/t_w:.2f}x"))
        if n == 8192 and n_cols == 2 and regime == "dup":
            _assert_rel_identical(semijoin_looped(a, b), semijoin(a, b))
            t_l = common.timeit(lambda: semijoin_looped(a, b))
            t_m = common.timeit(lambda: semijoin(a, b))
            rows.append(common.row(f"merge_join/{tag}_semi_looped", t_l, ""))
            rows.append(common.row(f"merge_join/{tag}_semi_merge", t_m,
                                   f"speedup={t_l/t_m:.2f}x"))
            iv = rng.integers(0, 1 << 20, (512, 2)).astype(np.int64)
            iv.sort(axis=1)
            ex = np.unique(rng.integers(0, 1 << 20, 2048).astype(np.int64))
            _assert_rel_identical(filter_in_ranges_looped(a, "va", iv, ex),
                                  filter_in_ranges(a, "va", iv, ex))
            t_l = common.timeit(lambda: filter_in_ranges_looped(a, "va",
                                                                iv, ex))
            t_m = common.timeit(lambda: filter_in_ranges(a, "va", iv, ex))
            rows.append(common.row(f"merge_join/{tag}_sip_looped", t_l, ""))
            rows.append(common.row(f"merge_join/{tag}_sip_merge", t_m,
                                   f"speedup={t_l/t_m:.2f}x"))
    # end-to-end: the engine's join_impl knob on one dataset/query
    ds = common.dataset("lgd")
    q = ds.queries[0]
    for impl in ("looped", "merge"):
        eng = StreakEngine(ds.store, ExecConfig(join_impl=impl))
        eng.execute(q)  # warm caches
        t = common.timeit(lambda: eng.execute(q))
        rows.append(common.row(f"merge_join/engine_lgd_{impl}", t, ""))
    return rows


def rank_stream() -> list:
    """Rank-pass streaming microbench: tables past the VMEM tile budget.

    The double-buffered kernel (kernels/merge_join.py) leaves the table
    planes in HBM and streams 8192-key tiles through a two-slot VMEM
    scratch, issuing tile j+1's DMA before tile j's compare pass — these
    rows sweep the table from VMEM-resident (1 tile) to 128 tiles so the
    >VMEM regime is on record. `interpret` rows run the actual Pallas
    streaming schedule (interpreter, CPU) and validate it at every size;
    they measure schedule correctness, not TPU throughput — `numpy` /
    `cpu` are the host baselines at each size.
    """
    rows = []
    rng = np.random.default_rng(11)
    m = 4096
    tn = 8192
    for n in (1 << 13, 1 << 17, 1 << 20):
        table = np.sort(rng.integers(0, 1 << 62, n))
        probes = rng.integers(0, 1 << 62, m)
        probes[: m // 8] = table[:: max(n // (m // 8), 1)][: m // 8]
        want_lo = np.searchsorted(table, probes, "left")
        want_hi = np.searchsorted(table, probes, "right")
        n_tiles = -(-n // tn)
        backends = ("numpy", "cpu") + (("interpret",) if n <= 1 << 17 else ())
        for backend in backends:
            lo, hi = kops.merge_join_ranks(table, probes, backend=backend)
            np.testing.assert_array_equal(np.asarray(lo), want_lo)
            np.testing.assert_array_equal(np.asarray(hi), want_hi)
            t = common.timeit(lambda: kops.merge_join_ranks(
                table, probes, backend=backend))
            rows.append(common.row(
                f"merge_join/rank_n{n}_m{m}_{backend}", t,
                f"tiles={n_tiles};vmem_scratch_bytes={2 * 2 * tn * 4}"))
    return rows


def engine_backends() -> list:
    """End-to-end engine time per Phase-3 backend on one dataset/query."""
    rows = []
    ds = common.dataset("lgd")
    q = ds.queries[0]
    for backend in ("numpy", "kernel", "fused"):
        eng = StreakEngine(ds.store, ExecConfig(join_backend=backend))
        eng.execute(q)  # warm caches / jit
        t = common.timeit(lambda: eng.execute(q))
        rows.append(common.row(f"fig8_join/backend_{backend}", t, ""))
    return rows


def run() -> list:
    rows = merge_join_micro()
    rows += rank_stream()
    rows += fused_vs_matrix()
    rows += engine_backends()
    for ds_name in ("yago3", "lgd"):
        ds = common.dataset(ds_name)
        for qi, q in enumerate(ds.queries):
            squad = StreakEngine(ds.store, ExecConfig(force_plan="S"))
            rtree = SyncRTreeEngine(ds.store)
            _, _, st_q = squad.execute(q)
            _, _, st_r = rtree.execute(q)
            t_q = common.timeit(lambda: squad.execute(q))
            t_r = common.timeit(lambda: rtree.execute(q))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_squadtree", t_q,
                f"cands={st_q.join.candidates}"))
            rows.append(common.row(
                f"fig8_join/{ds_name}/Q{qi+1}_sync_rtree", t_r,
                f"cands={st_r.join.candidates};"
                f"ratio={st_r.join.candidates/max(st_q.join.candidates,1):.1f}x"))
    return rows
