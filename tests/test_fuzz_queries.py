"""Differential query fuzzing: random spatial top-k queries on small
`synth_rdf` stores, STREAK vs the full-scan numpy oracle, bit-identical.

The generator sweeps query shape (class pair, distance/selectivity regime,
k, ranking weights, ASC/DESC, extra-pattern counts) and engine configuration
(join_impl, join/probe/rank backends, SIP lookahead width). Scores are
compared exactly — both engines accumulate the same f64 score keys in the
same term order, so any drift is a real soundness bug, not float noise.
(This harness is what caught the anisotropic `denormalize_distance`
pruning bug in core/geometry.py.)

Runs under real `hypothesis` when installed, or the fallback shim in
tests/_hypothesis_fallback.py (seeded random sampling) otherwise.

Set ``STREAK_FAULT_RATE`` (e.g. 0.02) to run the whole module under seeded
random fault injection at the kernel dispatch seam — the failover chains
must keep every differential property bit-identical. CI's faultlane job
does exactly this.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import fault
from repro.core.baselines import FullScanEngine
from repro.core.executor import ExecConfig, StreakEngine
from repro.core.query import Query, Ranking, SpatialFilter, TriplePattern, Var
from repro.data.synth_rdf import make_lgd


@pytest.fixture(scope="module", autouse=True)
def _fault_rate_from_env():
    """Optional module-wide fault injection (CI faultlane): every op chain
    sees a seeded `rate` of primary-attempt failures and must recover
    bit-identically through its fallbacks."""
    rate = float(os.environ.get("STREAK_FAULT_RATE", "0") or 0)
    if rate <= 0:
        yield
        return
    fault.STATE.reset()
    fault.install_plan(fault.FaultPlan(rate=rate, seed=7))
    try:
        yield
    finally:
        fault.STATE.reset()

# class -> extra (pa/pb-attached) predicates available for pattern-count
# fuzzing; mirrors the synth_rdf LGD catalog
CLASSES = {
    "class:hotel": ("name", "label", "stars"),
    "class:park": ("label", "area"),
    "class:police": ("name",),
    "class:road": ("name", "lanes"),
    "class:pub": ("name", "label"),
}

_DATASETS: dict = {}
_ENGINES: dict = {}
_ORACLE: dict = {}
_SHARDED: dict = {}


def _dataset(seed: int):
    if seed not in _DATASETS:
        _DATASETS[seed] = make_lgd(n_per_class=60, seed=seed, block=64)
    return _DATASETS[seed]


def _sharded_engine(seed: int, n_shards: int, **cfg) -> StreakEngine:
    from repro.core.shard import shard_store
    skey = (seed, n_shards)
    if skey not in _SHARDED:
        _SHARDED[skey] = shard_store(_dataset(seed).store, n_shards)
    ekey = (seed, n_shards, tuple(sorted(cfg.items())))
    if ekey not in _ENGINES:
        _ENGINES[ekey] = StreakEngine(_SHARDED[skey], ExecConfig(**cfg))
    return _ENGINES[ekey]


def _engine(seed: int, **cfg) -> StreakEngine:
    key = (seed, tuple(sorted(cfg.items())))
    if key not in _ENGINES:
        _ENGINES[key] = StreakEngine(_dataset(seed).store, ExecConfig(**cfg))
    return _ENGINES[key]


def _mk_query(seed, cls_a, cls_b, dist, k, w_a, w_b, descending,
              n_extra_a, n_extra_b) -> Query:
    """pair_query-shaped random query: two reified-type confidence-ranked
    sides joined by a spatial distance filter."""
    ns = _dataset(seed).ns
    pa, pb = Var("place"), Var("nplace")
    patterns = [
        TriplePattern(pa, Var("typePred1"), ns[cls_a], g=Var("r")),
        TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
        TriplePattern(pa, ns["hasGeometry"], Var("g1")),
        TriplePattern(pb, Var("typePred2"), ns[cls_b], g=Var("r1")),
        TriplePattern(Var("r1"), ns["hasConfidence"], Var("conf1")),
        TriplePattern(pb, ns["hasGeometry"], Var("g2")),
    ]
    for p in CLASSES[cls_a][:n_extra_a]:
        patterns.append(TriplePattern(pa, ns[p], Var(f"a_{p}")))
    for p in CLASSES[cls_b][:n_extra_b]:
        patterns.append(TriplePattern(pb, ns[p], Var(f"b_{p}")))
    return Query(
        select=(pa, pb), patterns=tuple(patterns),
        spatial=SpatialFilter(Var("g1"), Var("g2"), dist),
        ranking=Ranking(((Var("conf"), w_a), (Var("conf1"), w_b)),
                        descending=descending),
        k=k)


def _oracle_scores(seed, shape) -> np.ndarray:
    key = (seed, shape)
    if key not in _ORACLE:
        q = _mk_query(seed, *shape)
        scores, _, _ = FullScanEngine(_dataset(seed).store).execute(q)
        _ORACLE[key] = scores
    return _ORACLE[key]


def _check(seed, shape, **cfg):
    q = _mk_query(seed, *shape)
    want = _oracle_scores(seed, shape)
    got, rows, _ = _engine(seed, **cfg).execute(q)
    assert len(got) == len(want), (shape, cfg)
    # ties (clipped confidences) may permute boundary ROWS, never scores
    np.testing.assert_array_equal(np.sort(got), np.sort(want))
    assert rows.n == len(got)


# --------------------------------------------------------------------------
CLS = sorted(CLASSES)

# query shape: class pair, selectivity regime, k, weights, direction,
# extra-pattern counts (weights snapped to a grid so the oracle cache hits)
QSHAPE = st.tuples(
    st.sampled_from(CLS), st.sampled_from(CLS),
    st.sampled_from([1.5, 3.0, 6.0, 12.0]),          # dist: high -> low sel.
    st.sampled_from([1, 3, 10, 40, 150]),            # k
    st.sampled_from([0.25, 1.0, 1.75]),              # w_a
    st.sampled_from([0.5, 1.0, 2.0]),                # w_b
    st.booleans(),                                   # descending
    st.integers(0, 3), st.integers(0, 2),            # extra pattern counts
)

ECONF = st.tuples(
    st.sampled_from(["merge", "looped"]),            # join_impl
    st.sampled_from(["numpy", "fused"]),             # join_backend
    st.sampled_from([None, "numpy", "interpret"]),   # probe_backend
    st.sampled_from([None, "numpy", "cpu"]),         # rank_backend
    st.sampled_from([1, 3, 8]),                      # sip_lookahead
)

SEED = st.sampled_from([0, 1])


@settings(max_examples=25, deadline=None)
@given(SEED, QSHAPE, ECONF)
def test_fuzz_engine_matches_full_scan(seed, shape, econf):
    join_impl, join_backend, probe_backend, rank_backend, lookahead = econf
    _check(seed, shape,
           join_impl=join_impl, join_backend=join_backend,
           probe_backend=probe_backend, rank_backend=rank_backend,
           sip_lookahead=lookahead, fused_batch_cols=256)


@settings(max_examples=15, deadline=None)
@given(QSHAPE)
def test_fuzz_serving_matches_full_scan(shape):
    """The same differential property through the multi-tenant slot loop:
    a fuzzed query batched against two fixed companions must still match
    the oracle exactly."""
    from repro.serve.spatial import SpatialServeEngine
    ds = _dataset(0)
    q = _mk_query(0, *shape)
    companions = [ds.queries[0], ds.queries[3]]
    srv = SpatialServeEngine(
        ds.store, ExecConfig(join_backend="fused", fused_batch_cols=256,
                             kcap_auto=True), max_slots=3)
    reqs = srv.serve([q] + companions)
    want = _oracle_scores(0, shape)
    np.testing.assert_array_equal(np.sort(reqs[0].scores), np.sort(want))


@settings(max_examples=15, deadline=None)
@given(SEED, QSHAPE, st.sampled_from([2, 4, 8]),
       st.sampled_from(["numpy", "fused"]))
def test_fuzz_sharded_matches_unsharded(seed, shape, n_shards, join_backend):
    """Shard-count invariance under fuzzed query shapes: the Morton-prefix
    sharded engine must be BIT-identical (same rows, same order — not just
    the same score multiset) to the unsharded engine, which itself matches
    the full-scan oracle."""
    q = _mk_query(seed, *shape)
    cfg = dict(join_backend=join_backend, fused_batch_cols=256)
    got0, rows0, _ = _engine(seed, **cfg).execute(q)
    got1, rows1, _ = _sharded_engine(seed, n_shards, **cfg).execute(q)
    np.testing.assert_array_equal(got1, got0)
    assert rows1.keys() == rows0.keys()
    for c in rows0:
        np.testing.assert_array_equal(rows1[c], rows0[c])
    np.testing.assert_array_equal(np.sort(got1),
                                  np.sort(_oracle_scores(seed, shape)))


# ---------------------------------------------------- deterministic axes ---
# exhaustive backend matrix on two fixed shapes: guarantees every axis value
# is exercised even when the fuzz sampler (or the fallback shim) misses one
_FIXED = [
    ("class:hotel", "class:park", 6.0, 25, 1.0, 1.0, False, 1, 0),
    ("class:pub", "class:police", 3.0, 10, 1.75, 0.5, True, 2, 1),
]


@pytest.mark.parametrize("join_impl", ["merge", "looped"])
@pytest.mark.parametrize("join_backend", ["numpy", "fused"])
@pytest.mark.parametrize("lookahead", [1, 8])
def test_backend_matrix_matches_oracle(join_impl, join_backend, lookahead):
    for shape in _FIXED:
        _check(0, shape, join_impl=join_impl, join_backend=join_backend,
               sip_lookahead=lookahead, fused_batch_cols=256)


@pytest.mark.parametrize("probe_backend", [None, "numpy", "interpret"])
def test_probe_backends_match_oracle(probe_backend):
    _check(0, _FIXED[0], probe_backend=probe_backend)


@pytest.mark.parametrize("rank_backend", [None, "numpy", "cpu"])
def test_rank_backends_match_oracle(rank_backend):
    _check(0, _FIXED[1], rank_backend=rank_backend)


@pytest.mark.parametrize("descend", ["numpy", "kernel", "interpret"])
def test_descend_backends_match_oracle(descend):
    from repro import BackendPolicy
    _check(0, _FIXED[0], policy=BackendPolicy(descend=descend))


# ------------------------------------- legacy knobs vs BackendPolicy form --
# every legacy per-stage kwarg combination must be BIT-identical (same rows,
# same order — not just same score multiset) to its policy equivalent
_LEGACY_GRID = [
    {"join_impl": "looped"},
    {"join_backend": "fused", "kcap_auto": True},
    {"probe_backend": "interpret", "rank_backend": "cpu"},
    {"join_backend": "kernel", "join_impl": "merge", "rank_backend": "numpy"},
]
_STAGE_OF = {"join_backend": "join", "join_impl": "impl",
             "probe_backend": "probe", "rank_backend": "rank"}


@pytest.mark.parametrize("legacy", _LEGACY_GRID,
                         ids=lambda d: "+".join(sorted(d)))
def test_legacy_knobs_bit_identical_to_policy(legacy):
    import warnings

    from repro import BackendPolicy
    stages = {("kcap" if k == "kcap_auto" else _STAGE_OF[k]):
              (("auto" if v else "fixed") if k == "kcap_auto" else v)
              for k, v in legacy.items()}
    for shape in _FIXED:
        q = _mk_query(0, *shape)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            got_l, rows_l, _ = _engine(0, fused_batch_cols=256,
                                       **legacy).execute(q)
        got_p, rows_p, _ = _engine(0, fused_batch_cols=256,
                                   policy=BackendPolicy(**stages)).execute(q)
        np.testing.assert_array_equal(got_l, got_p)
        assert rows_l.keys() == rows_p.keys()
        for c in rows_p:
            np.testing.assert_array_equal(rows_l[c], rows_p[c])


# ------------------------------------------------- query-shape diversity --
# Geographica-shaped non-top-k shapes (core/shapes.py): range / within /
# kNN / spatial join, each bit-identical to its FullScanEngine brute-force
# oracle — rows AND order, not just score multisets (shape output uses a
# canonical deterministic ordering, so exact comparison is well-defined).

_SHAPE_ORACLE: dict = {}


def _mk_shape_query(seed, kind, cls_a, cls_b, p1, p2) -> Query:
    ns = _dataset(seed).ns
    pa, pb = Var("place"), Var("nplace")
    patterns = [
        TriplePattern(pa, Var("typePred1"), ns[cls_a], g=Var("r")),
        TriplePattern(Var("r"), ns["hasConfidence"], Var("conf")),
        TriplePattern(pa, ns["hasGeometry"], Var("g1")),
        TriplePattern(pb, Var("typePred2"), ns[cls_b], g=Var("r1")),
        TriplePattern(Var("r1"), ns["hasConfidence"], Var("conf1")),
        TriplePattern(pb, ns["hasGeometry"], Var("g2")),
    ]
    if kind == "range":
        spatial = SpatialFilter(Var("g1"), None,
                                window=(p1, p2, p1 + 30.0, p2 + 22.0))
    elif kind == "within":
        spatial = SpatialFilter(Var("g1"), None, dist=p2,
                                center=(p1, 100.0 - p1))
    elif kind == "knn":
        spatial = SpatialFilter(Var("g1"), Var("g2"), knn=int(p1))
    else:  # join
        spatial = SpatialFilter(Var("g1"), Var("g2"), dist=p1)
    return Query(select=(pa, pb), patterns=tuple(patterns),
                 spatial=spatial, ranking=None)


def _shape_oracle(seed, sshape):
    key = (seed, sshape)
    if key not in _SHAPE_ORACLE:
        q = _mk_shape_query(seed, *sshape)
        _SHAPE_ORACLE[key] = FullScanEngine(_dataset(seed).store).execute(q)
    return _SHAPE_ORACLE[key]


def _check_shape(seed, sshape, engine):
    q = _mk_shape_query(seed, *sshape)
    want_s, want_r, _ = _shape_oracle(seed, sshape)
    got_s, got_r, _ = engine.execute(q)
    np.testing.assert_array_equal(got_s, want_s)
    assert sorted(got_r.keys()) == sorted(want_r.keys()), (sshape,)
    for c in want_r.keys():
        np.testing.assert_array_equal(got_r[c], want_r[c])


_SHAPE_PARAMS = {
    # kind -> (p1 choices, p2 choices); see _mk_shape_query for meaning
    "range": ([0.0, 25.0, 60.0, 95.0], [0.0, 40.0, 80.0]),
    "within": ([5.0, 30.0, 50.0, 90.0], [0.0, 1.5, 8.0, 25.0]),  # p2 = dist
    "knn": ([1.0, 2.0, 5.0, 1000.0], [0.0]),                     # p1 = k
    "join": ([0.25, 2.0, 6.0], [0.0]),                           # p1 = dist
}


@st.composite
def _sshape_strategy(draw):
    kind = draw(st.sampled_from(sorted(_SHAPE_PARAMS)))
    p1s, p2s = _SHAPE_PARAMS[kind]
    return (kind, draw(st.sampled_from(CLS)), draw(st.sampled_from(CLS)),
            draw(st.sampled_from(p1s)), draw(st.sampled_from(p2s)))


SSHAPE = _sshape_strategy()

SECONF = st.tuples(
    st.sampled_from(["merge", "looped"]),            # join_impl
    st.sampled_from(["numpy", "kernel", "fused"]),   # join_backend
    st.sampled_from([None, "interpret"]),            # probe_backend
)


@settings(max_examples=25, deadline=None)
@given(SEED, SSHAPE, SECONF)
def test_fuzz_shapes_match_full_scan(seed, sshape, econf):
    join_impl, join_backend, probe_backend = econf
    eng = _engine(seed, join_impl=join_impl, join_backend=join_backend,
                  probe_backend=probe_backend, fused_batch_cols=256)
    _check_shape(seed, sshape, eng)


@settings(max_examples=15, deadline=None)
@given(SEED, SSHAPE, st.sampled_from([2, 4]))
def test_fuzz_shapes_sharded_match_full_scan(seed, sshape, n_shards):
    _check_shape(seed, sshape, _sharded_engine(seed, n_shards))


# fixed-seed regression corpus: shapes that exercised real bugs during
# development (kNN certification + pair-score keying, empty driven sides,
# window slivers, zero-radius within) plus one of each kind per class mix —
# deterministic, no sampler involved
_SHAPE_CORPUS = [
    ("range", "class:hotel", "class:park", 25.0, 40.0),
    ("range", "class:pub", "class:police", 95.0, 80.0),     # mostly empty
    ("within", "class:park", "class:road", 50.0, 0.0),      # zero radius
    ("within", "class:hotel", "class:pub", 30.0, 25.0),
    ("knn", "class:hotel", "class:park", 2.0, 0.0),         # cert. doubling
    ("knn", "class:police", "class:pub", 1000.0, 0.0),      # k > candidates
    ("join", "class:hotel", "class:park", 6.0, 0.0),
    ("join", "class:road", "class:police", 0.25, 0.0),      # near-empty
]


@pytest.mark.parametrize("sshape", _SHAPE_CORPUS,
                         ids=lambda s: f"{s[0]}-{s[1][6:]}-{s[2][6:]}")
def test_shape_regression_corpus(sshape):
    _check_shape(0, sshape, _engine(0))
    _check_shape(0, sshape, _sharded_engine(0, 4))


@pytest.mark.parametrize("descend", ["numpy", "interpret"])
def test_shape_descend_backends_match_oracle(descend):
    from repro import BackendPolicy
    for sshape in _SHAPE_CORPUS[::3]:
        _check_shape(0, sshape, _engine(0, policy=BackendPolicy(
            descend=descend)))
