"""End-to-end engine tests: STREAK vs oracle equivalence on synthetic data.

The FullScanEngine evaluates queries exhaustively (no early termination, no
SIP, no adaptive plans) and is the correctness oracle. Every STREAK
configuration (APS / fixed N / fixed S / SIP off / sync-R-tree join) must
return the same top-k score multiset.
"""
import numpy as np
import pytest

from repro.core.baselines import FullScanEngine, SyncRTreeEngine
from repro.core.executor import ExecConfig, StreakEngine
from repro.data import synth_rdf


@pytest.fixture(scope="module")
def lgd():
    return synth_rdf.make_lgd(n_per_class=150, seed=0, block=128)


@pytest.fixture(scope="module")
def yago():
    return synth_rdf.make_yago(n_places=600, seed=1, block=128)


@pytest.fixture(scope="module")
def quickstart():
    """(store, query) from the examples/quickstart.py workload."""
    import importlib.util
    import pathlib
    spec = importlib.util.spec_from_file_location(
        "quickstart", pathlib.Path(__file__).resolve().parents[1]
        / "examples" / "quickstart.py")
    qs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(qs)
    return qs.build_demo()


def _scores_match(a: np.ndarray, b: np.ndarray):
    """Top-k score multisets must match (ties may permute rows)."""
    np.testing.assert_allclose(np.sort(a), np.sort(b), rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("qi", range(8))
def test_streak_matches_fullscan_lgd(lgd, qi):
    q = lgd.queries[qi]
    oracle_scores, _, _ = FullScanEngine(lgd.store).execute(q)
    scores, rows, stats = StreakEngine(lgd.store).execute(q)
    assert len(scores) == len(oracle_scores)
    _scores_match(scores, oracle_scores)


@pytest.mark.parametrize("qi", range(8))
def test_streak_matches_fullscan_yago(yago, qi):
    q = yago.queries[qi]
    oracle_scores, _, _ = FullScanEngine(yago.store).execute(q)
    scores, rows, stats = StreakEngine(yago.store).execute(q)
    assert len(scores) == len(oracle_scores)
    _scores_match(scores, oracle_scores)


@pytest.mark.parametrize("qi", [0, 1, 5])
@pytest.mark.parametrize("cfg_name,cfg", [
    ("fixed_n", ExecConfig(force_plan="N")),
    ("fixed_s", ExecConfig(force_plan="S")),
    ("no_sip", ExecConfig(use_sip=False)),
    ("small_blocks", ExecConfig(block=64)),
])
def test_plan_variants_equivalent(lgd, qi, cfg_name, cfg):
    q = lgd.queries[qi]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, _ = StreakEngine(lgd.store, cfg).execute(q)
    _scores_match(ref, got)


@pytest.mark.parametrize("qi", [0, 2])
def test_sync_rtree_engine_equivalent(lgd, qi):
    q = lgd.queries[qi]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, _ = SyncRTreeEngine(lgd.store).execute(q)
    _scores_match(ref, got)


def test_early_termination_happens(lgd):
    q = lgd.queries[0]
    q = type(q)(select=q.select, patterns=q.patterns, spatial=q.spatial,
                ranking=q.ranking, k=1)
    scores, rows, stats = StreakEngine(lgd.store).execute(q)
    assert len(scores) == 1
    # with k=1 on an ASC ranking over exponential confidences the scan must
    # stop long before exhausting all driver blocks
    assert stats.early_terminated or stats.driver_blocks <= 2


def test_sip_reduces_driven_rows(lgd):
    # Q2 (park near police, small distance) is highly selective: SIP must
    # reduce the rows entering the spatial join relative to no-SIP
    q = lgd.queries[1]
    _, _, s_on = StreakEngine(lgd.store, ExecConfig(force_plan="S")).execute(q)
    _, _, s_off = StreakEngine(
        lgd.store, ExecConfig(force_plan="S", use_sip=False)).execute(q)
    assert s_on.driven_rows_after_sip < s_off.driven_rows_after_sip
    assert s_on.join.pairs_tested < s_off.join.pairs_tested


def test_aps_chooses_both_plans_somewhere(lgd, yago):
    """Across the benchmark, APS should exercise both N and S plans."""
    seen = set()
    for ds in (lgd, yago):
        for q in ds.queries:
            _, _, st = StreakEngine(ds.store).execute(q)
            seen.update(st.plan_log)
    assert "N" in seen and "S" in seen


def test_topk_k_prefix_property(lgd):
    """top-10 must be a prefix of top-50 (same scores)."""
    q = lgd.queries[0]
    q10 = type(q)(select=q.select, patterns=q.patterns, spatial=q.spatial,
                  ranking=q.ranking, k=10)
    q50 = type(q)(select=q.select, patterns=q.patterns, spatial=q.spatial,
                  ranking=q.ranking, k=50)
    s10, _, _ = StreakEngine(lgd.store).execute(q10)
    s50, _, _ = StreakEngine(lgd.store).execute(q50)
    np.testing.assert_allclose(s10, s50[:len(s10)], rtol=1e-9)


def test_theta_aware_refine_matches_oracle_and_skips_work(lgd):
    """θ-aware chunked refinement: same results as the exhaustive oracle
    while the stats show candidate pairs were skipped without refinement."""
    skipped_total = 0
    for qi in range(8):
        q = lgd.queries[qi]
        oracle, _, _ = StreakEngine(
            lgd.store, ExecConfig(use_sip=False)).execute(q)
        got, _, st = StreakEngine(
            lgd.store, ExecConfig(refine_chunk=64)).execute(q)
        _scores_match(got, oracle)
        skipped_total += st.join.refine_skipped
    assert skipped_total > 0


def test_kernel_backend_equivalent(lgd):
    """The Pallas-kernel Phase-3 backend (jnp ref path on CPU) matches."""
    q = lgd.queries[0]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, _ = StreakEngine(lgd.store,
                             ExecConfig(join_backend="kernel")).execute(q)
    _scores_match(ref, got)


@pytest.mark.parametrize("qi", range(8))
def test_fused_backend_equivalent_lgd(lgd, qi):
    """The streaming fused backend must return the same top-k multiset."""
    q = lgd.queries[qi]
    ref, _, _ = StreakEngine(lgd.store).execute(q)
    got, _, st = StreakEngine(
        lgd.store,
        ExecConfig(join_backend="fused", fused_batch_cols=256)).execute(q)
    _scores_match(ref, got)


@pytest.mark.parametrize("qi", [0, 3, 6])
def test_fused_backend_equivalent_yago(yago, qi):
    q = yago.queries[qi]
    ref, _, _ = StreakEngine(yago.store).execute(q)
    got, _, _ = StreakEngine(
        yago.store, ExecConfig(join_backend="fused")).execute(q)
    _scores_match(ref, got)


@pytest.mark.parametrize("qi", [0, 3, 5])
def test_join_impl_settings_equivalent(lgd, qi):
    """Top-k identical across `join_impl` settings (merge vs looped oracle),
    with identical per-block APS routing. Q1/Q6 take an APS plan switch
    mid-query (their plan_log mixes S and N blocks), so the merge join is
    exercised on both the S-Plan full scan and the N-Plan block path."""
    q = lgd.queries[qi]
    ref, _, st_l = StreakEngine(
        lgd.store, ExecConfig(join_impl="looped")).execute(q)
    got, _, st_m = StreakEngine(
        lgd.store, ExecConfig(join_impl="merge")).execute(q)
    _scores_match(ref, got)
    assert st_m.plan_log == st_l.plan_log
    if qi in (0, 5):  # the impl knob must not change APS's routing
        assert len(set(st_m.plan_log)) > 1


def test_join_impl_quickstart_bit_identical(quickstart):
    """Same ids, same scores across join_impl settings on the
    examples/quickstart.py workload."""
    store, q = quickstart
    s1, r1, _ = StreakEngine(
        store, ExecConfig(block=16, join_impl="looped")).execute(q)
    s2, r2, _ = StreakEngine(
        store, ExecConfig(block=16, join_impl="merge")).execute(q)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(r1["region"], r2["region"])
    np.testing.assert_array_equal(r1["river"], r2["river"])


def test_fused_backend_quickstart_bit_identical(quickstart):
    """Acceptance: same ids, same scores as the numpy backend on the
    examples/quickstart.py workload (tiny batch size forces several
    θ-consuming batches per block)."""
    store, q = quickstart
    s1, r1, _ = StreakEngine(store, ExecConfig(block=16)).execute(q)
    s2, r2, _ = StreakEngine(
        store, ExecConfig(block=16, join_backend="fused",
                          fused_batch_cols=8)).execute(q)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    np.testing.assert_array_equal(r1["region"], r2["region"])
    np.testing.assert_array_equal(r1["river"], r2["river"])
