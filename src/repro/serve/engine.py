"""Batched serving engine: continuous batching over a shared KV cache.

Slot-based decode (vLLM-lite): a fixed pool of `max_batch` slots, each with
its own cursor into the shared (L, B, S, Hkv, Dh) cache; requests join free
slots, decode steps run the whole pool, finished sequences free their slot.
The decode step is the same jitted `decode_step` the dry-run lowers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model_mod, params, cfg, max_batch: int = 8,
                 max_seq: int = 512, temperature: float = 0.0):
        self.mod = model_mod
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.cache = model_mod.init_cache(cfg, max_batch, max_seq)
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.slot_req: list = [None] * max_batch
        self.queue: list = []
        self._step = jax.jit(
            lambda p, c, t, q: model_mod.decode_step(p, c, t, q, cfg))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                self.pos[slot] = 0
                # prefill the prompt token-by-token through decode (simple,
                # exact; bulk prefill uses forward_with_cache)
                for tok in req.prompt[:-1]:
                    self._advance_slot(slot, tok)
                req._next = req.prompt[-1]

    def _advance_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros(self.max_batch, dtype=np.int32)
        tokens[slot] = token
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        self.pos[slot] += 1
        return int(jnp.argmax(logits[slot]))

    def step(self) -> int:
        """One engine iteration over every active slot; returns #active."""
        self._admit()
        active = [s for s in range(self.max_batch)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        tokens = np.zeros(self.max_batch, dtype=np.int32)
        for s in active:
            tokens[s] = self.slot_req[s]._next
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.pos[s] += 1
            req.out.append(int(nxt[s]))
            req._next = int(nxt[s])
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_seq - 1:
                req.done = True
                self.slot_req[s] = None
        return len(active)

    def run(self) -> None:
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
